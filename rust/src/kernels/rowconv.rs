//! Row-convolution inner loops — the Vector Slide algorithm.
//!
//! Every sliding convolution (1-D signals, 2-D image rows) reduces to the
//! same inner routine: given a padded source row, a filter row `w[0..k)`,
//! and a destination row, accumulate
//!
//! ```text
//! dst[i] += Σ_j  w[j] · src[i + j]        (i = 0 .. out_len)
//! ```
//!
//! vectorised over `i`: one `LANES`-wide block of outputs is produced from
//! the already-loaded registers covering `src[i .. i + LANES + k)`; the
//! window at tap `j` is a register-pair *slide* — no re-reads, no `im2col`
//! copies. Three variants, exactly the paper's three implementations:
//!
//! * [`row_conv_generic`] — filter widths `k ≤ LANES + 1` (17 on AVX-512):
//!   two registers per block, `slide_dyn` per tap ("the straightforward
//!   version of the Vector Slide algorithm").
//! * [`row_conv_compound`] — any width: a [`crate::simd::CompoundF32`] of `R` registers
//!   treated as one long vector ("kernels of larger width … operate on
//!   multiple hardware vectors treating them as a single long compound
//!   vector").
//! * [`row_conv_custom3`] / [`row_conv_custom5`] — fully unrolled k=3 and
//!   k=5 with compile-time slides, "custom kernels with optimal number of
//!   operations".
//!
//! Plus the reduced-precision members of the family (the paper's closing
//! low-memory-devices argument): [`row_conv_q8`] — int8 codes with an
//! exact i32 accumulator — and [`row_conv_bf16`] — bf16 storage with f32
//! accumulation. Both stream the padded row with the same no-`im2col`
//! access pattern; neither has a register-pair width constraint, so one
//! kernel each covers every filter width.
//!
//! SAFETY CONTRACT (checked by `debug_assert!`): callers must pad `src` so
//! that `src[out_len - 1 + k - 1 + 2*LANES]` is readable; `pad2d`/`pad_row`
//! with `slack = 2*LANES + k` guarantees this. (The row tail is handled by
//! one *partial* vector block — masked store — instead of a scalar loop:
//! a scalar tail costs up to 50% of a row when `out_len % LANES` is large,
//! the k=18 cliff in EXPERIMENTS.md §Perf.)

use crate::simd::{slide, slide_dyn, F32xL, IsaLevel, LANES};

/// Largest filter width the generic in-vector kernel handles: a window at
/// tap `k-1` must still come from one register pair, so `k - 1 ≤ LANES`.
pub const GENERIC_MAX_K: usize = LANES + 1;

/// Largest filter width the compound kernel supports (8 registers).
pub const COMPOUND_MAX_K: usize = 7 * LANES + 1;

/// Largest total tap count (`c_in/groups · kh · kw`) whose int8
/// convolution accumulator provably cannot overflow i32: each tap
/// contributes at most `128 · 128` in magnitude (`-128` codes can
/// appear through saturating quantization), so `i32::MAX / 128²` ≈
/// 131k taps are always safe — e.g. every `c_in ≤ 453` network at
/// k = 17. The conv-level `_q8` entry points assert this bound so
/// overflow is loud rather than a silent wrap.
pub const Q8_MAX_TAPS: usize = i32::MAX as usize / (128 * 128);

#[inline(always)]
fn src_ok(src: &[f32], out_len: usize, k: usize) -> bool {
    out_len == 0 || src.len() >= out_len - 1 + k - 1 + 2 * LANES + 1
}

/// Drive `block` over every `LANES`-wide output block, including one
/// final *partial* block for the row tail (masked load/store of the
/// `out_len % LANES` remaining columns). `block(x, acc)` must return the
/// accumulator for output columns `[x, x + LANES)`.
#[inline(always)]
fn run_blocks(dst: &mut [f32], out_len: usize, mut block: impl FnMut(usize, F32xL) -> F32xL) {
    let mut x = 0;
    while x + LANES <= out_len {
        let acc = block(x, F32xL::load(&dst[x..]));
        acc.store(&mut dst[x..]);
        x += LANES;
    }
    if x < out_len {
        let n = out_len - x;
        let acc = block(x, F32xL::load_partial(&dst[x..out_len], 0.0));
        acc.store_partial(&mut dst[x..out_len], n);
    }
}

/// Generic Vector Slide row convolution, `k ≤ GENERIC_MAX_K`.
#[inline]
pub fn row_conv_generic(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    let k = w.len();
    debug_assert!(k >= 1 && k <= GENERIC_MAX_K, "generic kernel k={k}");
    debug_assert!(src_ok(src, out_len, k), "source row under-padded");
    debug_assert!(dst.len() >= out_len);

    // PERF: two output blocks per iteration. Each block's accumulator is
    // a serial FMA chain (latency-bound at ~4 cycles/tap); running two
    // independent chains under the *same* per-tap dispatch doubles
    // throughput without disturbing LLVM's jump-table for slide_dyn.
    // (A 4-chain single-block unroll was tried first and measured ~2x
    // SLOWER — it defeats the jump-table layout; EXPERIMENTS.md §Perf.)
    let mut x = 0;
    while x + 2 * LANES <= out_len {
        let a0 = F32xL::load(&src[x..]);
        let b0 = F32xL::load(&src[x + LANES..]);
        let c0 = F32xL::load(&src[x + 2 * LANES..]);
        let mut acc0 = F32xL::load(&dst[x..]);
        let mut acc1 = F32xL::load(&dst[x + LANES..]);
        for (j, &wj) in w.iter().enumerate() {
            let wv = F32xL::splat(wj);
            acc0 = wv.mul_add(slide_dyn(a0, b0, j), acc0);
            acc1 = wv.mul_add(slide_dyn(b0, c0, j), acc1);
        }
        acc0.store(&mut dst[x..]);
        acc1.store(&mut dst[x + LANES..]);
        x += 2 * LANES;
    }
    run_blocks(&mut dst[x..out_len], out_len - x, |xr, mut acc| {
        let xr = x + xr;
        let a = F32xL::load(&src[xr..]);
        let b = F32xL::load(&src[xr + LANES..]);
        for (j, &wj) in w.iter().enumerate() {
            acc = F32xL::splat(wj).mul_add(slide_dyn(a, b, j), acc);
        }
        acc
    });
}

/// Compound-vector row convolution for arbitrary `k ≤ COMPOUND_MAX_K`.
///
/// The compound vector is traversed one register *pair* at a time: taps
/// `j ∈ [r·LANES, (r+1)·LANES)` all slide within the pair
/// `(x_r, x_{r+1})`, which lives in two named locals (PERF: an indexed
/// register array would be kept on the stack by LLVM, turning every
/// window into memory traffic — the k=18 cliff in EXPERIMENTS.md §Perf).
#[inline]
pub fn row_conv_compound(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    let k = w.len();
    debug_assert!(k >= 1 && k <= COMPOUND_MAX_K, "compound kernel k={k}");
    debug_assert!(src_ok(src, out_len, k), "source row under-padded");
    // Register groups: taps [r*LANES, (r+1)*LANES) per group.
    let groups = k.div_ceil(LANES);
    // PERF: two output blocks per iteration, same rationale as
    // row_conv_generic (two independent FMA chains under one dispatch).
    let mut x = 0;
    while x + 2 * LANES <= out_len {
        let mut acc0 = F32xL::load(&dst[x..]);
        let mut acc1 = F32xL::load(&dst[x + LANES..]);
        for r in 0..groups {
            let base = r * LANES;
            let a = F32xL::load(&src[x + base..]);
            let b = F32xL::load(&src[x + base + LANES..]);
            let c = F32xL::load(&src[x + base + 2 * LANES..]);
            let hi = k.min(base + LANES);
            let wv = F32xL::splat(w[base]);
            acc0 = wv.mul_add(a, acc0);
            acc1 = wv.mul_add(b, acc1);
            for (j, &wj) in w[base + 1..hi].iter().enumerate() {
                let wv = F32xL::splat(wj);
                acc0 = wv.mul_add(slide_dyn(a, b, j + 1), acc0);
                acc1 = wv.mul_add(slide_dyn(b, c, j + 1), acc1);
            }
        }
        acc0.store(&mut dst[x..]);
        acc1.store(&mut dst[x + LANES..]);
        x += 2 * LANES;
    }
    run_blocks(&mut dst[x..out_len], out_len - x, |xr, mut acc| {
        let xr = x + xr;
        for r in 0..groups {
            let base = r * LANES;
            let a = F32xL::load(&src[xr + base..]);
            let b = F32xL::load(&src[xr + base + LANES..]);
            let hi = k.min(base + LANES);
            acc = F32xL::splat(w[base]).mul_add(a, acc);
            for (j, &wj) in w[base + 1..hi].iter().enumerate() {
                acc = F32xL::splat(wj).mul_add(slide_dyn(a, b, j + 1), acc);
            }
        }
        acc
    });
}

/// Custom k = 3 kernel: compile-time slides, no dispatch, minimal shuffles
/// (2 per output vector).
#[inline]
pub fn row_conv_custom3(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    debug_assert_eq!(w.len(), 3);
    debug_assert!(src_ok(src, out_len, 3), "source row under-padded");
    let (w0, w1, w2) = (F32xL::splat(w[0]), F32xL::splat(w[1]), F32xL::splat(w[2]));
    run_blocks(dst, out_len, |x, mut acc| {
        let a = F32xL::load(&src[x..]);
        let b = F32xL::load(&src[x + LANES..]);
        acc = w0.mul_add(a, acc);
        acc = w1.mul_add(slide::<1>(a, b), acc);
        acc = w2.mul_add(slide::<2>(a, b), acc);
        acc
    });
}

/// Custom k = 5 kernel: compile-time slides, 4 shuffles per output vector.
#[inline]
pub fn row_conv_custom5(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    debug_assert_eq!(w.len(), 5);
    debug_assert!(src_ok(src, out_len, 5), "source row under-padded");
    let w0 = F32xL::splat(w[0]);
    let w1 = F32xL::splat(w[1]);
    let w2 = F32xL::splat(w[2]);
    let w3 = F32xL::splat(w[3]);
    let w4 = F32xL::splat(w[4]);
    run_blocks(dst, out_len, |x, mut acc| {
        let a = F32xL::load(&src[x..]);
        let b = F32xL::load(&src[x + LANES..]);
        acc = w0.mul_add(a, acc);
        acc = w1.mul_add(slide::<1>(a, b), acc);
        acc = w2.mul_add(slide::<2>(a, b), acc);
        acc = w3.mul_add(slide::<3>(a, b), acc);
        acc = w4.mul_add(slide::<4>(a, b), acc);
        acc
    });
}

/// Quantized int8 row convolution: `dst[i] += Σ_j w[j] · src[i + j]`
/// with i8 codes and an exact i32 accumulator.
///
/// This is the `_q8` member of the row-kernel family. Integer MACs have
/// no register-pair slide constraint, so one kernel covers **every**
/// filter width (no generic/compound split): the inner loop widens
/// `i8 → i32` and accumulates a `LANES`-wide block of outputs per tap,
/// which LLVM autovectorizes (`vpmovsxbd` + `vpmulld`/`vpmaddwd`-class
/// code with `-C target-cpu=native`). The sliding property is the same
/// as in f32 — the padded row is streamed once per tap with **no
/// im2col materialisation** — which is where the int8 speedup over the
/// int8 GEMM baseline comes from.
///
/// The caller quantizes symmetrically (`zero_point == 0` for both
/// operands — see [`crate::tensor::QuantParams`]), so zero padding is
/// the code 0 and no zero-point correction term is needed. Because the
/// accumulator is exact, this kernel and the int8 im2col+GEMM baseline
/// agree **bit for bit** (the kernel-equivalence suite asserts it).
///
/// `src` must be padded like the f32 kernels' rows (`2·LANES + k` right
/// slack).
///
/// The i32 accumulator is exact only while the convolution's total tap
/// count stays at or below [`Q8_MAX_TAPS`]; the conv-level q8 entry
/// points assert that bound, so overflow is loud rather than a silent
/// wrap.
#[inline]
pub fn row_conv_q8(src: &[i8], w: &[i8], dst: &mut [i32], out_len: usize) {
    let k = w.len();
    debug_assert!(k >= 1, "empty filter");
    debug_assert!(
        out_len == 0 || src.len() >= out_len - 1 + k - 1 + LANES + 1,
        "source row under-padded"
    );
    debug_assert!(dst.len() >= out_len);
    let mut x = 0;
    while x + LANES <= out_len {
        let mut acc = [0i32; LANES];
        for (j, &wj) in w.iter().enumerate() {
            let wv = wj as i32;
            let win = &src[x + j..x + j + LANES];
            for (a, &s) in acc.iter_mut().zip(win) {
                *a += wv * s as i32;
            }
        }
        for (d, a) in dst[x..x + LANES].iter_mut().zip(acc) {
            *d += a;
        }
        x += LANES;
    }
    for (i, d) in dst[x..out_len].iter_mut().enumerate() {
        let mut acc = 0i32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj as i32 * src[x + i + j] as i32;
        }
        *d += acc;
    }
}

/// bfloat16 row convolution: bf16 storage, f32 accumulation.
///
/// The `_bf16` member of the row-kernel family: the source row is bf16
/// (half the memory traffic of f32), each load widens to f32 with a
/// 16-bit shift, and the weight row arrives pre-widened to f32 (one
/// conversion per convolution, not per row). Accumulation is ordinary
/// f32, so the result differs from the f32 kernel only by the storage
/// rounding of the inputs. Like the int8 kernel there is no register
/// width constraint, so one kernel covers every filter width.
///
/// `src` must be padded like the f32 kernels' rows.
#[inline]
pub fn row_conv_bf16(src: &[crate::tensor::Bf16], w: &[f32], dst: &mut [f32], out_len: usize) {
    let k = w.len();
    debug_assert!(k >= 1, "empty filter");
    debug_assert!(
        out_len == 0 || src.len() >= out_len - 1 + k - 1 + LANES + 1,
        "source row under-padded"
    );
    debug_assert!(dst.len() >= out_len);
    let mut x = 0;
    while x + LANES <= out_len {
        let mut acc = [0.0f32; LANES];
        for (j, &wj) in w.iter().enumerate() {
            let win = &src[x + j..x + j + LANES];
            for (a, s) in acc.iter_mut().zip(win) {
                *a += wj * s.to_f32();
            }
        }
        for (d, a) in dst[x..x + LANES].iter_mut().zip(acc) {
            *d += a;
        }
        x += LANES;
    }
    for (i, d) in dst[x..out_len].iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * src[x + i + j].to_f32();
        }
        *d += acc;
    }
}

/// Pick the fastest row kernel for filter width `k` — the paper's §2
/// selection policy (custom for 3/5, generic to 17, compound beyond).
#[inline]
pub fn row_conv_auto(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    match w.len() {
        3 => row_conv_custom3(src, w, dst, out_len),
        5 => row_conv_custom5(src, w, dst, out_len),
        k if k <= GENERIC_MAX_K => row_conv_generic(src, w, dst, out_len),
        _ => row_conv_compound(src, w, dst, out_len),
    }
}

/// The three row-kernel families, as a *value* — what the paper's §2
/// policy chooses between, and what a measured
/// [`crate::autotune::DispatchProfile`] records as the per-width winner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowKernel {
    /// Fully unrolled custom kernels ([`row_conv_custom3`] /
    /// [`row_conv_custom5`]); widths 3 and 5 only.
    Custom,
    /// The generic in-vector Vector Slide ([`row_conv_generic`]),
    /// widths up to [`GENERIC_MAX_K`].
    Generic,
    /// The compound multi-register kernel ([`row_conv_compound`]),
    /// widths up to [`COMPOUND_MAX_K`].
    Compound,
}

impl RowKernel {
    /// All families, in report order.
    pub const ALL: [RowKernel; 3] = [RowKernel::Custom, RowKernel::Generic, RowKernel::Compound];

    /// Stable name used in reports and `profile.json`.
    pub fn name(self) -> &'static str {
        match self {
            RowKernel::Custom => "custom",
            RowKernel::Generic => "generic",
            RowKernel::Compound => "compound",
        }
    }

    /// Parse a stable name (inverse of [`RowKernel::name`]).
    pub fn parse(s: &str) -> Option<RowKernel> {
        Self::ALL.into_iter().find(|r| r.name() == s)
    }

    /// Whether this family can evaluate filter width `k`.
    pub fn supports(self, k: usize) -> bool {
        match self {
            RowKernel::Custom => k == 3 || k == 5,
            RowKernel::Generic => k >= 1 && k <= GENERIC_MAX_K,
            RowKernel::Compound => k >= 1 && k <= COMPOUND_MAX_K,
        }
    }

    /// The paper's §2 selection for width `k` (custom 3/5 → generic ≤
    /// [`GENERIC_MAX_K`] → compound). This is the fallback every tuned
    /// lookup reduces to when no profile is present.
    ///
    /// # Panics
    /// If `k` exceeds [`COMPOUND_MAX_K`] (callers fall back to the
    /// direct kernel before any row kernel is chosen).
    pub fn paper_policy(k: usize) -> RowKernel {
        assert!(k >= 1 && k <= COMPOUND_MAX_K, "no row kernel for width {k}");
        match k {
            3 | 5 => RowKernel::Custom,
            _ if k <= GENERIC_MAX_K => RowKernel::Generic,
            _ => RowKernel::Compound,
        }
    }

    /// This family if it can evaluate `k`, else the paper policy for `k`
    /// — the clamp that keeps a nearest-bucket profile lookup (or a
    /// hand-edited profile) from ever selecting an illegal kernel.
    pub fn legal_for(self, k: usize) -> RowKernel {
        if self.supports(k) {
            self
        } else {
            RowKernel::paper_policy(k)
        }
    }

    /// The concrete row routine for width `k` at the process's effective
    /// ISA level ([`IsaLevel::effective`] — the detected level, or the
    /// `--isa`-forced one).
    ///
    /// Total even on out-of-family widths: an unsupported pick quietly
    /// re-clamps through [`RowKernel::legal_for`], so callers can feed a
    /// profile choice straight in.
    pub fn row_fn(self, k: usize) -> fn(&[f32], &[f32], &mut [f32], usize) {
        self.row_fn_at(k, IsaLevel::effective())
    }

    /// The concrete row routine for width `k` at an explicit [`IsaLevel`].
    ///
    /// Total in *both* arguments: the family re-clamps through
    /// [`RowKernel::legal_for`], and a level this machine (or build)
    /// cannot execute resolves to the portable kernel — requesting
    /// `Neon` on x86-64, `Avx512` under a pre-1.89 toolchain, or any
    /// intrinsic level on a machine without the feature is never UB,
    /// just the scalar path. Every intrinsic routine is bit-identical to
    /// its portable counterpart (the `isa_parity` suite pins this), so
    /// the level only moves throughput, never results.
    ///
    /// On x86-64 both AVX2 and AVX-512 serve the Generic and Compound
    /// families with one any-width streaming kernel: at 8/16 f32 per
    /// unaligned L1 load the register-pair slide economy that splits the
    /// portable families is not worth a shuffle port — only the custom
    /// k=3/5 kernels keep the paper's slide form (see `simd::x86`).
    pub fn row_fn_at(self, k: usize, isa: IsaLevel) -> fn(&[f32], &[f32], &mut [f32], usize) {
        let family = self.legal_for(k);
        match isa {
            #[cfg(target_arch = "x86_64")]
            IsaLevel::Avx2 => match family {
                RowKernel::Custom if k == 3 => accel::custom3_avx2,
                RowKernel::Custom => accel::custom5_avx2,
                RowKernel::Generic | RowKernel::Compound => accel::f32_avx2,
            },
            #[cfg(all(target_arch = "x86_64", swconv_avx512))]
            IsaLevel::Avx512 => match family {
                RowKernel::Custom if k == 3 => accel::custom3_avx512,
                RowKernel::Custom => accel::custom5_avx512,
                RowKernel::Generic | RowKernel::Compound => accel::f32_avx512,
            },
            #[cfg(target_arch = "aarch64")]
            IsaLevel::Neon => match family {
                RowKernel::Custom if k == 3 => accel::custom3_neon,
                RowKernel::Custom => accel::custom5_neon,
                RowKernel::Generic | RowKernel::Compound => accel::f32_neon,
            },
            _ => match family {
                RowKernel::Custom if k == 3 => row_conv_custom3,
                RowKernel::Custom => row_conv_custom5,
                RowKernel::Generic => row_conv_generic,
                RowKernel::Compound => row_conv_compound,
            },
        }
    }
}

/// The int8 row routine at an explicit [`IsaLevel`] — the quantized
/// member of [`RowKernel::row_fn_at`]'s dispatch. One kernel covers
/// every filter width (no family split), so the level is the only
/// dimension. All variants produce **identical** i32 accumulators
/// (integer arithmetic is exact); unavailable levels resolve to the
/// portable [`row_conv_q8`]. AVX-512 reuses the AVX2 integer kernel —
/// the pair-madd form has no AVX-512F equivalent (`vpmaddwd` at 512 bits
/// needs AVX-512BW) and the i8 path is memory-bound anyway.
pub fn row_conv_q8_at(isa: IsaLevel) -> fn(&[i8], &[i8], &mut [i32], usize) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2 | IsaLevel::Avx512 => accel::q8_avx2,
        #[cfg(target_arch = "aarch64")]
        IsaLevel::Neon => accel::q8_neon,
        _ => row_conv_q8,
    }
}

/// The bf16 row routine at an explicit [`IsaLevel`] — the bf16 member
/// of [`RowKernel::row_fn_at`]'s dispatch. Like the int8 kernel there is
/// no family split. All variants accumulate in the portable kernel's
/// exact (non-fused) order, so results are bit-identical across levels;
/// unavailable levels resolve to the portable [`row_conv_bf16`].
/// AVX-512 reuses the AVX2 expand-multiply kernel (the widening shuffle
/// at 512 bits needs AVX-512BW).
pub fn row_conv_bf16_at(
    isa: IsaLevel,
) -> fn(&[crate::tensor::Bf16], &[f32], &mut [f32], usize) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2 | IsaLevel::Avx512 => accel::bf16_avx2,
        #[cfg(target_arch = "aarch64")]
        IsaLevel::Neon => accel::bf16_neon,
        _ => row_conv_bf16,
    }
}

/// Safe dispatch shims around the x86-64 `std::arch` kernels
/// (`simd::x86`): each shim *hard-asserts* the padding/length contract
/// (the intrinsic kernels read full vectors past `out_len`, so an
/// under-padded row must panic like the portable path would, never read
/// out of bounds), verifies ISA availability, and falls back to the
/// portable kernel when the level is missing — which makes
/// [`RowKernel::row_fn_at`] total over levels on every machine.
#[cfg(target_arch = "x86_64")]
mod accel {
    use super::*;
    use crate::simd::x86;
    use crate::tensor::Bf16;

    #[inline]
    fn assert_f32_contract(src: &[f32], k: usize, dst: &[f32], out_len: usize) {
        assert!(k >= 1, "empty filter");
        assert!(src_ok(src, out_len, k), "source row under-padded");
        assert!(dst.len() >= out_len);
    }

    /// The narrower q8/bf16 slack: `LANES + 1` f32 past the last window.
    #[inline]
    fn assert_narrow_contract(src_len: usize, k: usize, dst_len: usize, out_len: usize) {
        assert!(k >= 1, "empty filter");
        assert!(
            out_len == 0 || src_len >= out_len - 1 + k - 1 + LANES + 1,
            "source row under-padded"
        );
        assert!(dst_len >= out_len);
    }

    pub(super) fn custom3_avx2(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 3);
        assert_f32_contract(src, 3, dst, out_len);
        if IsaLevel::Avx2.available() {
            // SAFETY: AVX2+FMA verified available; contract asserted.
            unsafe { x86::row_conv_custom3_avx2(src, w, dst, out_len) }
        } else {
            row_conv_custom3(src, w, dst, out_len)
        }
    }

    pub(super) fn custom5_avx2(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 5);
        assert_f32_contract(src, 5, dst, out_len);
        if IsaLevel::Avx2.available() {
            // SAFETY: AVX2+FMA verified available; contract asserted.
            unsafe { x86::row_conv_custom5_avx2(src, w, dst, out_len) }
        } else {
            row_conv_custom5(src, w, dst, out_len)
        }
    }

    pub(super) fn f32_avx2(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_f32_contract(src, w.len(), dst, out_len);
        if IsaLevel::Avx2.available() {
            // SAFETY: AVX2+FMA verified available; contract asserted.
            unsafe { x86::row_conv_f32_avx2(src, w, dst, out_len) }
        } else {
            row_conv_auto(src, w, dst, out_len)
        }
    }

    pub(super) fn q8_avx2(src: &[i8], w: &[i8], dst: &mut [i32], out_len: usize) {
        assert_narrow_contract(src.len(), w.len(), dst.len(), out_len);
        if IsaLevel::Avx2.available() {
            // SAFETY: AVX2 verified available; contract asserted.
            unsafe { x86::row_conv_q8_avx2(src, w, dst, out_len) }
        } else {
            row_conv_q8(src, w, dst, out_len)
        }
    }

    pub(super) fn bf16_avx2(src: &[Bf16], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_narrow_contract(src.len(), w.len(), dst.len(), out_len);
        if IsaLevel::Avx2.available() {
            // SAFETY: Bf16 is #[repr(transparent)] over u16, so the raw
            // bit view is layout-identical; AVX2 verified available;
            // contract asserted.
            unsafe {
                let bits = std::slice::from_raw_parts(src.as_ptr().cast::<u16>(), src.len());
                x86::row_conv_bf16_avx2(bits, w, dst, out_len)
            }
        } else {
            row_conv_bf16(src, w, dst, out_len)
        }
    }

    #[cfg(swconv_avx512)]
    pub(super) fn custom3_avx512(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 3);
        assert_f32_contract(src, 3, dst, out_len);
        if IsaLevel::Avx512.available() {
            // SAFETY: AVX-512F verified available; contract asserted.
            unsafe { x86::row_conv_custom3_avx512(src, w, dst, out_len) }
        } else {
            row_conv_custom3(src, w, dst, out_len)
        }
    }

    #[cfg(swconv_avx512)]
    pub(super) fn custom5_avx512(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 5);
        assert_f32_contract(src, 5, dst, out_len);
        if IsaLevel::Avx512.available() {
            // SAFETY: AVX-512F verified available; contract asserted.
            unsafe { x86::row_conv_custom5_avx512(src, w, dst, out_len) }
        } else {
            row_conv_custom5(src, w, dst, out_len)
        }
    }

    #[cfg(swconv_avx512)]
    pub(super) fn f32_avx512(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_f32_contract(src, w.len(), dst, out_len);
        if IsaLevel::Avx512.available() {
            // SAFETY: AVX-512F verified available; contract asserted.
            unsafe { x86::row_conv_f32_avx512(src, w, dst, out_len) }
        } else {
            row_conv_auto(src, w, dst, out_len)
        }
    }
}

/// Safe dispatch shims around the aarch64 NEON kernels (`simd::neon`) —
/// same contract-then-call structure as the x86 shims.
#[cfg(target_arch = "aarch64")]
mod accel {
    use super::*;
    use crate::simd::neon;
    use crate::tensor::Bf16;

    #[inline]
    fn assert_f32_contract(src: &[f32], k: usize, dst: &[f32], out_len: usize) {
        assert!(k >= 1, "empty filter");
        assert!(src_ok(src, out_len, k), "source row under-padded");
        assert!(dst.len() >= out_len);
    }

    #[inline]
    fn assert_narrow_contract(src_len: usize, k: usize, dst_len: usize, out_len: usize) {
        assert!(k >= 1, "empty filter");
        assert!(
            out_len == 0 || src_len >= out_len - 1 + k - 1 + LANES + 1,
            "source row under-padded"
        );
        assert!(dst_len >= out_len);
    }

    pub(super) fn custom3_neon(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 3);
        assert_f32_contract(src, 3, dst, out_len);
        if IsaLevel::Neon.available() {
            // SAFETY: NEON verified available; contract asserted.
            unsafe { neon::row_conv_custom3_neon(src, w, dst, out_len) }
        } else {
            row_conv_custom3(src, w, dst, out_len)
        }
    }

    pub(super) fn custom5_neon(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_eq!(w.len(), 5);
        assert_f32_contract(src, 5, dst, out_len);
        if IsaLevel::Neon.available() {
            // SAFETY: NEON verified available; contract asserted.
            unsafe { neon::row_conv_custom5_neon(src, w, dst, out_len) }
        } else {
            row_conv_custom5(src, w, dst, out_len)
        }
    }

    pub(super) fn f32_neon(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_f32_contract(src, w.len(), dst, out_len);
        if IsaLevel::Neon.available() {
            // SAFETY: NEON verified available; contract asserted.
            unsafe { neon::row_conv_f32_neon(src, w, dst, out_len) }
        } else {
            row_conv_auto(src, w, dst, out_len)
        }
    }

    pub(super) fn q8_neon(src: &[i8], w: &[i8], dst: &mut [i32], out_len: usize) {
        assert_narrow_contract(src.len(), w.len(), dst.len(), out_len);
        if IsaLevel::Neon.available() {
            // SAFETY: NEON verified available; contract asserted.
            unsafe { neon::row_conv_q8_neon(src, w, dst, out_len) }
        } else {
            row_conv_q8(src, w, dst, out_len)
        }
    }

    pub(super) fn bf16_neon(src: &[Bf16], w: &[f32], dst: &mut [f32], out_len: usize) {
        assert_narrow_contract(src.len(), w.len(), dst.len(), out_len);
        if IsaLevel::Neon.available() {
            // SAFETY: Bf16 is #[repr(transparent)] over u16, so the raw
            // bit view is layout-identical; NEON verified available;
            // contract asserted.
            unsafe {
                let bits = std::slice::from_raw_parts(src.as_ptr().cast::<u16>(), src.len());
                neon::row_conv_bf16_neon(bits, w, dst, out_len)
            }
        } else {
            row_conv_bf16(src, w, dst, out_len)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{pad_row, XorShiftRng};

    /// Scalar reference.
    fn ref_conv(src: &[f32], w: &[f32], out_len: usize) -> Vec<f32> {
        (0..out_len)
            .map(|i| w.iter().enumerate().map(|(j, &wj)| wj * src[i + j]).sum())
            .collect()
    }

    fn run(kernel: fn(&[f32], &[f32], &mut [f32], usize), k: usize, out_len: usize, seed: u64) {
        let mut rng = XorShiftRng::new(seed);
        let raw: Vec<f32> = (0..out_len + k - 1).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..k).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let src = pad_row(&raw, 0, 2 * LANES + k, 0.0);
        let mut dst = vec![0.0f32; out_len];
        kernel(&src, &w, &mut dst, out_len);
        let expect = ref_conv(&src, &w, out_len);
        for i in 0..out_len {
            assert!(
                (dst[i] - expect[i]).abs() < 1e-4,
                "k={k} i={i}: {} vs {}",
                dst[i],
                expect[i]
            );
        }
    }

    #[test]
    fn generic_all_k() {
        for k in 1..=GENERIC_MAX_K {
            run(row_conv_generic, k, 100, k as u64);
        }
    }

    #[test]
    fn generic_short_rows_and_tails() {
        for out_len in [1, 2, LANES - 1, LANES, LANES + 1, 2 * LANES + 3] {
            run(row_conv_generic, 4, out_len, 99 + out_len as u64);
        }
    }

    #[test]
    fn compound_all_k_to_65() {
        for k in 2..=65 {
            run(row_conv_compound, k, 80, 1000 + k as u64);
        }
    }

    #[test]
    fn compound_max_width() {
        run(row_conv_compound, COMPOUND_MAX_K, 40, 7);
    }

    #[test]
    fn custom3_matches() {
        for out_len in [1, 16, 33, 100] {
            run(row_conv_custom3, 3, out_len, 5 + out_len as u64);
        }
    }

    #[test]
    fn custom5_matches() {
        for out_len in [1, 16, 33, 100] {
            run(row_conv_custom5, 5, out_len, 6 + out_len as u64);
        }
    }

    #[test]
    fn auto_selects_correctly_everywhere() {
        for k in [1, 2, 3, 5, 7, 16, 17, 18, 31, 33, 64] {
            run(row_conv_auto, k, 70, 2000 + k as u64);
        }
    }

    #[test]
    fn accumulates_into_dst() {
        let src = pad_row(&[1.0; 20], 0, 2 * LANES + 2, 0.0);
        let w = [1.0, 1.0];
        let mut dst = vec![10.0f32; 19];
        row_conv_generic(&src, &w, &mut dst, 19);
        assert!(dst.iter().all(|&v| v == 12.0));
    }

    #[test]
    fn zero_out_len_is_noop() {
        let src = vec![0.0; 64];
        let mut dst: Vec<f32> = vec![];
        row_conv_generic(&src, &[1.0, 2.0], &mut dst, 0);
    }

    #[test]
    fn q8_matches_scalar_reference_exactly() {
        for (k, out_len) in [(1usize, 40usize), (3, 100), (5, 33), (17, 50), (18, 50), (64, 20)] {
            let mut rng = XorShiftRng::new(7000 + k as u64);
            let raw: Vec<i8> =
                (0..out_len + k - 1).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
            let w: Vec<i8> = (0..k).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
            let src = pad_row(&raw, 0, 2 * LANES + k, 0i8);
            let mut dst = vec![5i32; out_len];
            row_conv_q8(&src, &w, &mut dst, out_len);
            for i in 0..out_len {
                let want: i32 = 5 + w
                    .iter()
                    .enumerate()
                    .map(|(j, &wj)| wj as i32 * src[i + j] as i32)
                    .sum::<i32>();
                assert_eq!(dst[i], want, "k={k} i={i}");
            }
        }
    }

    #[test]
    fn bf16_matches_f32_on_exactly_representable_inputs() {
        use crate::tensor::Bf16;
        // Small integers are exactly representable in bf16, so the bf16
        // row kernel must agree with the f32 reference exactly.
        for (k, out_len) in [(3usize, 40usize), (9, 50), (33, 20)] {
            let mut rng = XorShiftRng::new(8000 + k as u64);
            let raw: Vec<f32> =
                (0..out_len + k - 1).map(|_| rng.uniform(-8.0, 8.0).round()).collect();
            let w: Vec<f32> = (0..k).map(|_| rng.uniform(-4.0, 4.0).round()).collect();
            let srcf = pad_row(&raw, 0, 2 * LANES + k, 0.0f32);
            let src: Vec<Bf16> = srcf.iter().map(|&v| Bf16::from_f32(v)).collect();
            let mut dst = vec![0.0f32; out_len];
            row_conv_bf16(&src, &w, &mut dst, out_len);
            let expect = ref_conv(&srcf, &w, out_len);
            for i in 0..out_len {
                assert!(
                    (dst[i] - expect[i]).abs() < 1e-3,
                    "k={k} i={i}: {} vs {}",
                    dst[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn q8_zero_out_len_is_noop() {
        let src = vec![0i8; 64];
        let mut dst: Vec<i32> = vec![];
        row_conv_q8(&src, &[1, 2], &mut dst, 0);
    }

    #[test]
    fn row_kernel_names_roundtrip() {
        for r in RowKernel::ALL {
            assert_eq!(RowKernel::parse(r.name()), Some(r));
        }
        assert_eq!(RowKernel::parse("mystery"), None);
    }

    #[test]
    fn row_kernel_paper_policy_matches_auto() {
        assert_eq!(RowKernel::paper_policy(3), RowKernel::Custom);
        assert_eq!(RowKernel::paper_policy(5), RowKernel::Custom);
        assert_eq!(RowKernel::paper_policy(4), RowKernel::Generic);
        assert_eq!(RowKernel::paper_policy(GENERIC_MAX_K), RowKernel::Generic);
        assert_eq!(RowKernel::paper_policy(GENERIC_MAX_K + 1), RowKernel::Compound);
        assert_eq!(RowKernel::paper_policy(COMPOUND_MAX_K), RowKernel::Compound);
    }

    #[test]
    fn row_kernel_legal_for_clamps() {
        // Custom picked for a width it cannot evaluate → paper policy.
        assert_eq!(RowKernel::Custom.legal_for(4), RowKernel::Generic);
        assert_eq!(RowKernel::Custom.legal_for(3), RowKernel::Custom);
        // Generic beyond its reach → compound.
        assert_eq!(
            RowKernel::Generic.legal_for(GENERIC_MAX_K + 1),
            RowKernel::Compound
        );
        assert_eq!(RowKernel::Compound.legal_for(2), RowKernel::Compound);
    }

    #[test]
    fn row_fn_total_and_correct() {
        // Every family × a width it may or may not support: row_fn must
        // hand back a routine that computes the right answer for k.
        for rk in RowKernel::ALL {
            for k in [2usize, 3, 5, 9, GENERIC_MAX_K, GENERIC_MAX_K + 4] {
                run(rk.row_fn(k), k, 50, 3000 + k as u64);
            }
        }
    }

    #[test]
    fn row_fn_at_total_and_correct_for_every_level() {
        // Every family × every ISA level — including levels this machine
        // (or build) cannot execute, which must resolve to the portable
        // kernel rather than fault. The exhaustive bit-parity sweep
        // lives in tests/isa_parity.rs; this pins totality + accuracy.
        for isa in IsaLevel::ALL {
            for rk in RowKernel::ALL {
                for k in [1usize, 3, 5, 9, GENERIC_MAX_K, GENERIC_MAX_K + 4] {
                    run(rk.row_fn_at(k, isa), k, 50, 4000 + k as u64);
                }
            }
        }
    }

    #[test]
    fn q8_dispatch_is_exact_for_every_level() {
        for isa in IsaLevel::ALL {
            let kernel = row_conv_q8_at(isa);
            for (k, out_len) in [(1usize, 40usize), (3, 100), (17, 50), (64, 20)] {
                let mut rng = XorShiftRng::new(9000 + k as u64);
                let raw: Vec<i8> =
                    (0..out_len + k - 1).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
                let w: Vec<i8> = (0..k).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
                let src = pad_row(&raw, 0, 2 * LANES + k, 0i8);
                let mut dst = vec![5i32; out_len];
                kernel(&src, &w, &mut dst, out_len);
                for i in 0..out_len {
                    let want: i32 = 5 + w
                        .iter()
                        .enumerate()
                        .map(|(j, &wj)| wj as i32 * src[i + j] as i32)
                        .sum::<i32>();
                    assert_eq!(dst[i], want, "isa={} k={k} i={i}", isa.name());
                }
            }
        }
    }

    #[test]
    fn bf16_dispatch_matches_portable_bitwise_for_every_level() {
        use crate::tensor::Bf16;
        for isa in IsaLevel::ALL {
            let kernel = row_conv_bf16_at(isa);
            for (k, out_len) in [(3usize, 40usize), (9, 50), (33, 20)] {
                let mut rng = XorShiftRng::new(9500 + k as u64);
                let raw: Vec<f32> =
                    (0..out_len + k - 1).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let w: Vec<f32> = (0..k).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let srcf = pad_row(&raw, 0, 2 * LANES + k, 0.0f32);
                let src: Vec<Bf16> = srcf.iter().map(|&v| Bf16::from_f32(v)).collect();
                let mut want = vec![0.25f32; out_len];
                row_conv_bf16(&src, &w, &mut want, out_len);
                let mut got = vec![0.25f32; out_len];
                kernel(&src, &w, &mut got, out_len);
                assert_eq!(got, want, "isa={} k={k}", isa.name());
            }
        }
    }
}
