//! 1-D Sliding Window primitives: vector-slide convolution and the
//! log-step sliding window sum (the algorithm family of the paper's
//! precursor, arXiv:2305.16513, whose ~log(k) speedup §2 recalls).

use super::direct::conv1d_direct_epi_ctx;
use super::epilogue::Epilogue;
use super::rowconv::{row_conv_bf16_at, row_conv_q8_at, RowKernel, COMPOUND_MAX_K};
use super::Conv1dParams;
use crate::exec::ExecCtx;
use crate::simd::{slide_dyn, F32xL, LANES};
use crate::tensor::{pad_row, pad_row_into, Bf16, QuantParams, Tensor, TensorT, WeightScales};

/// 1-D convolution via the Vector Slide kernels.
///
/// * `x` — `[c_in, l]`, `w` — `[c_out, c_in, k]`; returns `[c_out, l_out]`.
///
/// Stride 1 runs the sliding kernel directly; larger strides compute the
/// stride-1 result per row and subsample (the paper only evaluates unit
/// stride). Filter widths beyond [`COMPOUND_MAX_K`] fall back to the
/// direct kernel.
pub fn conv1d_sliding(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Sliding, |ctx| {
        conv1d_sliding_ctx(x, w, bias, p, ctx)
    })
}

/// [`conv1d_sliding`] with an execution context: the padded channels and
/// the per-worker accumulator come from the ctx's scratch arena, and
/// output rows fan out over the ctx's threads (bit-identical for any
/// thread count).
pub fn conv1d_sliding_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    conv1d_sliding_epi_ctx(x, w, Epilogue::from_bias(bias), p, ctx)
}

/// [`conv1d_sliding_ctx`] with a fused output [`Epilogue`] — bias seeds
/// the accumulator as always, a requested ReLU is applied at the output
/// write (bit-identical to a separate ReLU pass).
pub fn conv1d_sliding_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 2, "input must be [c, l]");
    assert_eq!(w.rank(), 3, "weights must be [cout, cin, k]");
    let (c_in, l) = (x.dim(0), x.dim(1));
    let (c_out, c_in_w, k) = (w.dim(0), w.dim(1), w.dim(2));
    assert_eq!(c_in, c_in_w, "c_in mismatch");
    if k > COMPOUND_MAX_K {
        return conv1d_direct_epi_ctx(x, w, epi, p, ctx);
    }
    let lo = p.out_len(l, k);
    // Unit-stride output length (subsampled later if stride > 1).
    let lo1 = l + 2 * p.pad - k + 1;

    // Pad every channel once into arena scratch: conv padding + right
    // slack for vector loads.
    let lp = l + 2 * p.pad + 2 * LANES + k;
    let mut padded = ctx.take(c_in * lp, 0.0);
    let xs = x.as_slice();
    for ci in 0..c_in {
        pad_row_into(&xs[ci * l..(ci + 1) * l], p.pad, &mut padded[ci * lp..(ci + 1) * lp]);
    }

    let ws = w.as_slice();
    let mut out = Tensor::zeros(&[c_out, lo]);
    let padded_ref: &[f32] = &padded;
    // Resolve the row routine once per conv: the paper's §2 family for
    // this width, at the ctx's ISA level.
    let row_fn = RowKernel::paper_policy(k).row_fn_at(k, ctx.isa());
    // Per-worker accumulator: one arena checkout per parallel region,
    // so steady-state arena traffic is deterministic and allocation-free.
    ctx.par_chunks_with(
        out.as_mut_slice(),
        lo,
        || ctx.take_unfilled(lo1),
        |co, orow, scratch| {
            let b = bias.map_or(0.0, |b| b[co]);
            scratch.fill(b);
            for ci in 0..c_in {
                let wrow = &ws[(co * c_in + ci) * k..(co * c_in + ci + 1) * k];
                row_fn(&padded_ref[ci * lp..], wrow, scratch, lo1);
            }
            if epi.relu {
                for (o, v) in orow.iter_mut().enumerate() {
                    *v = scratch[if p.stride == 1 { o } else { o * p.stride }].max(0.0);
                }
            } else if p.stride == 1 {
                orow.copy_from_slice(&scratch[..lo]);
            } else {
                for (o, v) in orow.iter_mut().enumerate() {
                    *v = scratch[o * p.stride];
                }
            }
        },
        |scratch| ctx.put(scratch),
    );
    ctx.put(padded);
    out
}

/// Quantized int8 1-D sliding convolution, raw i32 accumulator output
/// (`x` — `[c_in, l]` codes, `w` — `[c_out, c_in, k]` codes, both
/// symmetric). Mirrors [`conv1d_sliding_ctx`]'s pad-once / fan-out
/// structure with [`super::rowconv::row_conv_q8`]-contract rows
/// (dispatched per ISA via [`row_conv_q8_at`]); every width is supported (no
/// direct fallback needed).
pub fn conv1d_sliding_q8_raw_ctx(
    x: &TensorT<i8>,
    w: &TensorT<i8>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    assert_eq!(x.rank(), 2, "input must be [c, l]");
    assert_eq!(w.rank(), 3, "weights must be [cout, cin, k]");
    let (c_in, l) = (x.dim(0), x.dim(1));
    let (c_out, c_in_w, k) = (w.dim(0), w.dim(1), w.dim(2));
    assert_eq!(c_in, c_in_w, "c_in mismatch");
    assert!(
        c_in * k <= crate::kernels::rowconv::Q8_MAX_TAPS,
        "int8 conv with {} taps could overflow the i32 accumulator",
        c_in * k
    );
    let lo = p.out_len(l, k);
    let lo1 = l + 2 * p.pad - k + 1;

    let lp = l + 2 * p.pad + 2 * LANES + k;
    let mut padded: Vec<i8> = ctx.take_elems(c_in * lp, 0i8);
    let xs = x.as_slice();
    for ci in 0..c_in {
        pad_row_into(&xs[ci * l..(ci + 1) * l], p.pad, &mut padded[ci * lp..(ci + 1) * lp]);
    }

    let ws = w.as_slice();
    let mut out = TensorT::<i32>::zeros(&[c_out, lo]);
    let padded_ref: &[i8] = &padded;
    let row_fn = row_conv_q8_at(ctx.isa());
    ctx.par_chunks_with(
        out.as_mut_slice(),
        lo,
        || ctx.take_elems_unfilled::<i32>(lo1),
        |co, orow, scratch| {
            scratch.fill(0);
            for ci in 0..c_in {
                let wrow = &ws[(co * c_in + ci) * k..(co * c_in + ci + 1) * k];
                row_fn(&padded_ref[ci * lp..], wrow, scratch, lo1);
            }
            if p.stride == 1 {
                orow.copy_from_slice(&scratch[..lo]);
            } else {
                for (o, v) in orow.iter_mut().enumerate() {
                    *v = scratch[o * p.stride];
                }
            }
        },
        |scratch| ctx.put_elems(scratch),
    );
    ctx.put_elems(padded);
    out
}

/// [`conv1d_sliding_q8_raw_ctx`] with dequantized `f32` output
/// (`· x_scale · w_scale` + per-channel `bias`, through the dequant
/// shared with the 2-D paths). Both quantizations must be symmetric.
pub fn conv1d_sliding_q8_ctx(
    x: &TensorT<i8>,
    xq: QuantParams,
    w: &TensorT<i8>,
    wq: QuantParams,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    if let Some(b) = bias {
        assert_eq!(b.len(), w.dim(0), "bias length");
    }
    let raw = conv1d_sliding_q8_raw_ctx(x, w, p, ctx);
    super::sliding2d::dequantize_conv_acc(&raw, xq, &WeightScales::PerTensor(wq), bias, false)
}

/// bfloat16 1-D sliding convolution: bf16 storage in and out, f32
/// accumulation ([`super::rowconv::row_conv_bf16`]-contract rows via
/// [`row_conv_bf16_at`]; weights widened to f32 once per
/// call). Mirrors [`conv1d_sliding_ctx`].
pub fn conv1d_sliding_bf16_ctx(
    x: &TensorT<Bf16>,
    w: &TensorT<Bf16>,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> TensorT<Bf16> {
    assert_eq!(x.rank(), 2, "input must be [c, l]");
    assert_eq!(w.rank(), 3, "weights must be [cout, cin, k]");
    let (c_in, l) = (x.dim(0), x.dim(1));
    let (c_out, c_in_w, k) = (w.dim(0), w.dim(1), w.dim(2));
    assert_eq!(c_in, c_in_w, "c_in mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    let lo = p.out_len(l, k);
    let lo1 = l + 2 * p.pad - k + 1;

    let lp = l + 2 * p.pad + 2 * LANES + k;
    let mut padded: Vec<Bf16> = ctx.take_elems(c_in * lp, Bf16::ZERO);
    let xs = x.as_slice();
    for ci in 0..c_in {
        pad_row_into(&xs[ci * l..(ci + 1) * l], p.pad, &mut padded[ci * lp..(ci + 1) * lp]);
    }
    let mut wf: Vec<f32> = ctx.take_elems_unfilled(w.numel());
    for (d, s) in wf.iter_mut().zip(w.as_slice()) {
        *d = s.to_f32();
    }

    let mut out = TensorT::<Bf16>::zeros(&[c_out, lo]);
    let padded_ref: &[Bf16] = &padded;
    let wf_ref: &[f32] = &wf;
    let row_fn = row_conv_bf16_at(ctx.isa());
    ctx.par_chunks_with(
        out.as_mut_slice(),
        lo,
        || ctx.take_elems_unfilled::<f32>(lo1),
        |co, orow, scratch| {
            let b = bias.map_or(0.0, |b| b[co]);
            scratch.fill(b);
            for ci in 0..c_in {
                let wrow = &wf_ref[(co * c_in + ci) * k..(co * c_in + ci + 1) * k];
                row_fn(&padded_ref[ci * lp..], wrow, scratch, lo1);
            }
            for (o, v) in orow.iter_mut().enumerate() {
                *v = Bf16::from_f32(scratch[if p.stride == 1 { o } else { o * p.stride }]);
            }
        },
        |scratch| ctx.put_elems(scratch),
    );
    ctx.put_elems(wf);
    ctx.put_elems(padded);
    out
}

/// Log-step sliding window sum: `out[i] = Σ_{j<k} x[i+j]`.
///
/// Instead of `k − 1` adds per output, the window sum is built by
/// doubling: `S_{2m}[i] = S_m[i] + S_m[i+m]`, plus one add per set bit of
/// `k` — `O(log k)` vector operations per output vector. This is the core
/// "sliding window sum" algorithm (and the source of the logarithmic
/// speedup the paper's intro recalls for 1-D).
///
/// Requires `1 ≤ k ≤ LANES`; `x` must be padded so `x[out_len-1 + k-1]`
/// plus a `2·LANES` slack is readable (see [`sliding_sum`] for the
/// user-facing wrapper that pads).
pub fn sliding_sum_padded(x: &[f32], k: usize, dst: &mut [f32], out_len: usize) {
    assert!(k >= 1 && k <= LANES, "sliding_sum supports k in 1..=LANES, got {k}");
    debug_assert!(out_len == 0 || x.len() >= out_len - 1 + k - 1 + 3 * LANES);

    let mut i = 0;
    while i + LANES <= out_len {
        // Three registers cover every slide this block performs: the
        // doubling chain shifts by at most k-1 ≤ LANES-1 total per
        // register, so the valid prefix never drops below LANES lanes.
        let x0 = F32xL::load(&x[i..]);
        let x1 = F32xL::load(&x[i + LANES..]);
        let x2 = F32xL::load(&x[i + 2 * LANES..]);
        let s = sliding_sum_block(x0, x1, x2, k);
        s.store(&mut dst[i..]);
        i += LANES;
    }
    for o in i..out_len {
        dst[o] = (0..k).map(|j| x[o + j]).sum();
    }
}

/// One output register of the log-step window sum over `x0‖x1‖x2`.
#[inline]
fn sliding_sum_block(x0: F32xL, x1: F32xL, x2: F32xL, k: usize) -> F32xL {
    // s_* hold the running window sum over the compound vector; width is
    // the window length accumulated so far.
    let (mut s0, mut s1, mut s2) = (x0, x1, x2);
    let mut width = 1usize;
    // Consume the bits of k from the second-most-significant down:
    // double, then add one more element when the bit is set.
    let bits = usize::BITS - k.leading_zeros();
    for bit in (0..bits - 1).rev() {
        // Double: S_{2w}[i] = S_w[i] + S_w[i+w].
        let t0 = s0 + slide_dyn(s0, s1, width);
        let t1 = s1 + slide_dyn(s1, s2, width);
        let t2 = s2 + slide_dyn(s2, s2, width); // tail lanes garbage, never read
        (s0, s1, s2) = (t0, t1, t2);
        width *= 2;
        if (k >> bit) & 1 == 1 {
            // S_{w+1}[i] = S_w[i] + X[i+w].
            let t0 = s0 + slide_dyn(x0, x1, width);
            let t1 = s1 + slide_dyn(x1, x2, width);
            (s0, s1, s2) = (t0, t1, s2);
            width += 1;
        }
    }
    debug_assert_eq!(width, k);
    s0
}

/// User-facing sliding window sum over a signal: pads and runs
/// [`sliding_sum_padded`]. Returns `x.len() - k + 1` sums.
pub fn sliding_sum(x: &[f32], k: usize) -> Vec<f32> {
    assert!(k >= 1 && k <= x.len(), "window {k} vs signal {}", x.len());
    let out_len = x.len() - k + 1;
    let padded = pad_row(x, 0, 3 * LANES + k, 0.0);
    let mut dst = vec![0.0f32; out_len];
    sliding_sum_padded(&padded, k.min(LANES), &mut dst, out_len);
    if k > LANES {
        // Large windows: combine the LANES-wide log-step result serially.
        // (Pooling windows beyond the register width are rare; keep exact.)
        let mut out = vec![0.0f32; out_len];
        for (i, o) in out.iter_mut().enumerate() {
            *o = (0..k).map(|j| padded[i + j]).sum();
        }
        return out;
    }
    dst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::direct::conv1d_direct;
    use crate::kernels::Conv1dParams;
    use crate::tensor::XorShiftRng;

    fn ref_sliding_sum(x: &[f32], k: usize) -> Vec<f32> {
        (0..x.len() - k + 1)
            .map(|i| x[i..i + k].iter().sum())
            .collect()
    }

    #[test]
    fn sliding_sum_all_k() {
        let mut rng = XorShiftRng::new(3);
        let x: Vec<f32> = (0..200).map(|_| rng.uniform(-1.0, 1.0)).collect();
        for k in 1..=LANES {
            let got = sliding_sum(&x, k);
            let want = ref_sliding_sum(&x, k);
            assert_eq!(got.len(), want.len());
            for i in 0..got.len() {
                assert!((got[i] - want[i]).abs() < 1e-4, "k={k} i={i}");
            }
        }
    }

    #[test]
    fn sliding_sum_large_window_fallback() {
        let x: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let got = sliding_sum(&x, 40);
        let want = ref_sliding_sum(&x, 40);
        assert_eq!(got, want);
    }

    #[test]
    fn sliding_sum_short_signal() {
        let x = [1.0, 2.0, 3.0];
        assert_eq!(sliding_sum(&x, 3), vec![6.0]);
        assert_eq!(sliding_sum(&x, 1), vec![1.0, 2.0, 3.0]);
    }

    fn against_direct(c_in: usize, c_out: usize, l: usize, k: usize, p: Conv1dParams, seed: u64) {
        let x = Tensor::randn(&[c_in, l], seed);
        let w = Tensor::randn(&[c_out, c_in, k], seed + 1);
        let bias: Vec<f32> = (0..c_out).map(|i| 0.01 * i as f32).collect();
        let got = conv1d_sliding(&x, &w, Some(&bias), &p);
        let want = conv1d_direct(&x, &w, Some(&bias), &p);
        let d = got.max_abs_diff(&want);
        assert!(d < 1e-3, "cin={c_in} cout={c_out} l={l} k={k}: diff {d}");
    }

    #[test]
    fn conv1d_matches_direct_small_filters() {
        for k in [1, 2, 3, 5, 8] {
            against_direct(2, 3, 50, k, Conv1dParams::default(), 10 + k as u64);
        }
    }

    #[test]
    fn conv1d_matches_direct_generic_and_compound() {
        for k in [16, 17, 18, 33, 64] {
            against_direct(1, 2, 120, k, Conv1dParams::default(), 20 + k as u64);
        }
    }

    #[test]
    fn conv1d_matches_direct_padded() {
        against_direct(3, 2, 40, 7, Conv1dParams { stride: 1, pad: 3 }, 30);
    }

    #[test]
    fn conv1d_matches_direct_strided() {
        against_direct(2, 2, 41, 5, Conv1dParams { stride: 3, pad: 2 }, 31);
    }

    #[test]
    fn conv1d_huge_filter_falls_back() {
        against_direct(1, 1, 300, COMPOUND_MAX_K + 10, Conv1dParams::default(), 32);
    }
}
