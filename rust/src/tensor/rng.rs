//! Tiny deterministic RNG so the crate needs no external `rand` dependency
//! and every test / benchmark workload is reproducible from a seed.

/// xorshift64* generator with a Box–Muller Gaussian tap.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
    /// Cached second output of the Box–Muller pair.
    spare: Option<f64>,
}

impl XorShiftRng {
    /// Create a generator from a seed (any value; 0 is remapped).
    pub fn new(seed: u64) -> Self {
        XorShiftRng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
            spare: None,
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard-normal f32 (Box–Muller).
    pub fn gauss(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s as f32;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        (r * theta.cos()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShiftRng::new(7);
        let mut b = XorShiftRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = XorShiftRng::new(3);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&v));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShiftRng::new(4);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn gauss_moments_roughly_standard() {
        let mut r = XorShiftRng::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
