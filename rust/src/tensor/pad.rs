//! Zero-padding for the sliding kernels.
//!
//! The sliding-window kernels read the input through shifted vector loads:
//! the window at output column `x` spans input columns `x .. x+k`, and the
//! vectorised loop loads whole `LANES`-wide registers. To keep those loads
//! in-bounds for every output column (including row tails) the input is
//! padded **once** with the convolution padding plus a right *slack* of at
//! least `LANES + k` columns. This is `O(H · W)` extra memory versus the
//! `k²×` blow-up of `im2col` — the core of the paper's memory argument.

use super::dense::TensorT;
use super::element::Element;

/// Padded geometry for [`pad2d_into`]: `(hp, wp)` of an `[n, c, hp, wp]`
/// buffer for an `h × w` input with `ph`/`pw` padding and `slack_w`
/// right slack.
pub fn padded2d_size(h: usize, w: usize, ph: usize, pw: usize, slack_w: usize) -> (usize, usize) {
    (h + 2 * ph, w + 2 * pw + slack_w)
}

/// Copy `x` into a pre-filled padded buffer (any element type).
///
/// `dst` must hold `n · c · hp · wp` elements (see [`padded2d_size`])
/// already set to the pad value — kernels draw it from the
/// [`crate::exec::ExecCtx`] scratch arena with the fill applied — and
/// only the interior rows are written here. Returns `(hp, wp)`.
pub fn pad2d_into<E: Element>(
    x: &TensorT<E>,
    ph: usize,
    pw: usize,
    slack_w: usize,
    dst: &mut [E],
) -> (usize, usize) {
    assert_eq!(x.rank(), 4, "pad2d expects NCHW");
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (hp, wp) = padded2d_size(h, w, ph, pw, slack_w);
    assert_eq!(dst.len(), n * c * hp * wp, "padded buffer size");
    for ni in 0..n {
        for ci in 0..c {
            let src = x.plane(ni, ci);
            let plane = &mut dst[(ni * c + ci) * hp * wp..(ni * c + ci + 1) * hp * wp];
            for row in 0..h {
                let s = &src[row * w..row * w + w];
                let d = &mut plane[(row + ph) * wp + pw..(row + ph) * wp + pw + w];
                d.copy_from_slice(s);
            }
        }
    }
    (hp, wp)
}

/// Pad an NCHW tensor with `ph` rows / `pw` columns of `value` on each
/// side, plus `slack_w` extra columns of `value` on the right only.
///
/// Output shape: `[n, c, h + 2·ph, w + 2·pw + slack_w]`. Allocating
/// wrapper around [`pad2d_into`]; hot paths pad into arena scratch
/// instead.
pub fn pad2d<E: Element>(
    x: &TensorT<E>,
    ph: usize,
    pw: usize,
    slack_w: usize,
    value: E,
) -> TensorT<E> {
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (hp, wp) = padded2d_size(h, w, ph, pw, slack_w);
    let mut out = TensorT::full(&[n, c, hp, wp], value);
    pad2d_into(x, ph, pw, slack_w, out.as_mut_slice());
    out
}

/// Copy a row (1-D signal) into a pre-filled padded buffer: `x` lands at
/// `dst[p..p + x.len()]`; everything else keeps its pad value.
pub fn pad_row_into<E: Copy>(x: &[E], p: usize, dst: &mut [E]) {
    dst[p..p + x.len()].copy_from_slice(x);
}

/// Pad a single row (1-D signal) with `p` values on the left and
/// `p + slack` on the right. Allocating wrapper around [`pad_row_into`].
pub fn pad_row<E: Copy>(x: &[E], p: usize, slack: usize, value: E) -> Vec<E> {
    let mut out = vec![value; x.len() + 2 * p + slack];
    pad_row_into(x, p, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn pad2d_shape_and_values() {
        let x = Tensor::iota(&[1, 2, 2, 3]);
        let p = pad2d(&x, 1, 2, 4, 0.0);
        assert_eq!(p.dims(), &[1, 2, 4, 3 + 4 + 4]);
        // Interior preserved.
        assert_eq!(p.at4(0, 0, 1, 2), x.at4(0, 0, 0, 0));
        assert_eq!(p.at4(0, 1, 2, 4), x.at4(0, 1, 1, 2));
        // Border zero.
        assert_eq!(p.at4(0, 0, 0, 0), 0.0);
        assert_eq!(p.at4(0, 1, 3, 10), 0.0);
    }

    #[test]
    fn pad2d_value_fill() {
        let x = Tensor::zeros(&[1, 1, 1, 1]);
        let p = pad2d(&x, 1, 1, 0, f32::NEG_INFINITY);
        assert_eq!(p.at4(0, 0, 0, 0), f32::NEG_INFINITY);
        assert_eq!(p.at4(0, 0, 1, 1), 0.0);
    }

    #[test]
    fn pad2d_no_padding_copies() {
        let x = Tensor::iota(&[2, 1, 3, 3]);
        let p = pad2d(&x, 0, 0, 0, 0.0);
        assert_eq!(p, x);
    }

    #[test]
    fn pad_row_layout() {
        let r = pad_row(&[1.0, 2.0], 2, 3, 0.5);
        assert_eq!(r, vec![0.5, 0.5, 1.0, 2.0, 0.5, 0.5, 0.5, 0.5, 0.5]);
    }

    #[test]
    fn pad2d_into_matches_pad2d() {
        let x = Tensor::randn(&[2, 3, 4, 5], 9);
        let want = pad2d(&x, 1, 2, 3, -1.0);
        let (hp, wp) = padded2d_size(4, 5, 1, 2, 3);
        let mut dst = vec![-1.0f32; 2 * 3 * hp * wp];
        assert_eq!(pad2d_into(&x, 1, 2, 3, &mut dst), (hp, wp));
        assert_eq!(dst, want.as_slice());
    }

    #[test]
    #[should_panic(expected = "padded buffer size")]
    fn pad2d_into_rejects_wrong_size() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        let mut dst = vec![0.0f32; 3];
        pad2d_into(&x, 0, 0, 0, &mut dst);
    }
}
