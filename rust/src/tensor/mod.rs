//! Minimal owned-`f32` tensor library (NCHW convention for images).
//!
//! Everything the kernels need and nothing more: contiguous row-major
//! buffers, stride math, deterministic pseudo-random fills (no external
//! RNG dependency), comparison helpers for the test suite, and the
//! zero-padding used by the sliding kernels.
//!
//! Note on padding: the sliding kernels pad a tensor **once** with
//! `pad2d`, adding a `LANES`-sized right slack so shifted vector loads
//! never read out of bounds. That costs `O(H·W)` extra memory — compare
//! the `im2col` baseline which materialises a `k²`-times larger matrix
//! per convolution (the paper's "memory bloating problem").

mod dense;
mod pad;
mod rng;

pub use dense::Tensor;
pub use pad::{pad2d, pad2d_into, pad_row, pad_row_into, padded2d_size};
pub use rng::XorShiftRng;
