//! Minimal owned-buffer tensor library (NCHW convention for images),
//! generic over its element type.
//!
//! Everything the kernels need and nothing more: contiguous row-major
//! buffers ([`TensorT`], with [`Tensor`] = `TensorT<f32>`), stride math,
//! deterministic pseudo-random fills (no external RNG dependency),
//! comparison helpers for the test suite, the zero-padding used by the
//! sliding kernels, and the element layer ([`Element`], [`Dtype`],
//! [`Bf16`], [`QuantParams`]) that lets the same kernels run in f32,
//! bfloat16 or quantized int8.
//!
//! Note on padding: the sliding kernels pad a tensor **once** with
//! `pad2d`, adding a `LANES`-sized right slack so shifted vector loads
//! never read out of bounds. That costs `O(H·W)` extra memory — compare
//! the `im2col` baseline which materialises a `k²`-times larger matrix
//! per convolution (the paper's "memory bloating problem").

mod dense;
mod element;
mod pad;
mod rng;

pub use dense::{Tensor, TensorT};
pub use element::{
    dequantize, from_bf16, quantize, quantize_per_channel, to_bf16, Bf16, Dtype, Element,
    QuantParams, WeightScales,
};
pub use pad::{pad2d, pad2d_into, pad_row, pad_row_into, padded2d_size};
pub use rng::XorShiftRng;
