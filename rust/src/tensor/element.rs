//! The element layer: storage dtypes, quantization parameters, and the
//! [`Element`] trait that makes the tensor/exec/kernel stack
//! dtype-generic.
//!
//! The paper closes by arguing that sliding-window convolution "could
//! promote a wider adoption of AI on low-power and low-memory devices"
//! and is compatible with model-compression methods; the low-memory GEMM
//! line of work (Anderson et al., arXiv:1709.03395) makes the same case
//! for reduced precision. The slide primitives themselves are
//! element-type agnostic — everything they need from a scalar is
//! captured here:
//!
//! * [`Element`] — a storage scalar (`f32`, [`Bf16`], `i8`) plus its
//!   accumulator type (`f32` for the float dtypes, `i32` for `i8`).
//!   Adding a dtype is one trait impl, not a fork of the kernel tree.
//! * [`Dtype`] — the runtime tag ([`crate::exec::ExecCtx`] and
//!   `BackendSpec` carry one; the CLI's `--dtype` flag parses one).
//! * [`QuantParams`] — per-tensor affine quantization
//!   (`real = (code - zero_point) · scale`) with the symmetric
//!   constructors the int8 conv kernels expect, plus the tensor-level
//!   [`quantize`] / [`dequantize`] / [`to_bf16`] / [`from_bf16`]
//!   converters used at layer boundaries.

use super::dense::{Tensor, TensorT};

/// Runtime element-type tag.
///
/// `F32`, `Bf16` and `I8` are *serving* dtypes (what `--dtype` accepts
/// and what `BackendSpec`/`ExecCtx` carry); `I32` exists so the int8
/// kernels' raw accumulator output is itself a well-formed
/// [`TensorT`], and never appears on a serving knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 32-bit IEEE float — the pre-refactor behaviour, bit for bit.
    F32,
    /// bfloat16: u16 storage (top half of an f32), f32 accumulation.
    Bf16,
    /// Signed 8-bit integer codes under a per-tensor [`QuantParams`],
    /// i32 accumulation.
    I8,
    /// 32-bit integer — the i8 kernels' accumulator; storage-only.
    I32,
}

impl Dtype {
    /// Every tag, in report order.
    pub const ALL: [Dtype; 4] = [Dtype::F32, Dtype::Bf16, Dtype::I8, Dtype::I32];

    /// The dtypes a backend can serve (everything but the
    /// accumulator-only `I32`).
    pub const SERVING: [Dtype; 3] = [Dtype::F32, Dtype::Bf16, Dtype::I8];

    /// Stable name used by the CLI and `profile.json`.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
            Dtype::I8 => "i8",
            Dtype::I32 => "i32",
        }
    }

    /// Parse a stable name (inverse of [`Dtype::name`]).
    pub fn parse(s: &str) -> Option<Dtype> {
        Self::ALL.into_iter().find(|d| d.name() == s)
    }

    /// Storage width in bytes — what the byte-based arena accounting and
    /// the roofline traffic models scale by.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::Bf16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// A storage scalar the tensor/exec/kernel stack can be instantiated
/// over.
///
/// The trait carries exactly what the dtype-generic layers need: an
/// additive-zero default, `f32` conversions for the layer boundaries,
/// the accumulator type kernels sum in, and the runtime [`Dtype`] tag.
/// For `i8` the conversions are *raw code* casts — the affine mapping
/// between codes and reals lives in [`QuantParams`], per tensor, not in
/// the element.
pub trait Element:
    Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static
{
    /// What kernels accumulate partial sums in (`f32` for the float
    /// dtypes, `i32` for `i8` — exact, so int8 sliding and int8
    /// im2col-GEMM agree bit for bit).
    type Acc: Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static;

    /// Runtime tag for this element type.
    const DTYPE: Dtype;

    /// Lossy conversion from `f32` (rounding for [`Bf16`],
    /// round-and-saturate raw code for `i8`).
    fn from_f32(v: f32) -> Self;

    /// Widening conversion to `f32` (exact for every implementor).
    fn to_f32(self) -> f32;
}

impl Element for f32 {
    type Acc = f32;
    const DTYPE: Dtype = Dtype::F32;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        v
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self
    }
}

impl Element for i8 {
    type Acc = i32;
    const DTYPE: Dtype = Dtype::I8;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        // Saturating cast (Rust `as` saturates): the *affine* mapping is
        // QuantParams' job; this is the raw-code conversion.
        v.round() as i8
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}

impl Element for i32 {
    type Acc = i32;
    const DTYPE: Dtype = Dtype::I32;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        v.round() as i32
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}

/// bfloat16: the top 16 bits of an IEEE f32 (1 sign, 8 exponent, 7
/// mantissa bits).
///
/// Stored as a `u16` newtype; conversion to `f32` is a shift (exact),
/// conversion from `f32` rounds to nearest-even — both compile to a
/// couple of integer ops, so the bf16 kernels pay conversion in
/// registers while halving storage traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Positive zero.
    pub const ZERO: Bf16 = Bf16(0);

    /// Round an `f32` to the nearest bfloat16 (ties to even). NaN is
    /// preserved as a quiet NaN.
    #[inline(always)]
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // Quiet the payload so truncation can't produce an infinity.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round-to-nearest-even on the truncated 16 bits.
        let round = ((bits >> 16) & 1) + 0x7FFF;
        Bf16(((bits + round) >> 16) as u16)
    }

    /// Widen to `f32` (exact).
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl Element for Bf16 {
    type Acc = f32;
    const DTYPE: Dtype = Dtype::Bf16;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        Bf16::from_f32(v)
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        Bf16::to_f32(self)
    }
}

/// Per-tensor affine quantization parameters:
/// `real = (code − zero_point) · scale`.
///
/// The int8 conv kernels require **symmetric** parameters
/// (`zero_point == 0`) for both activations and weights — the
/// accumulator is then just `Σ x_code · w_code`, zero padding is the
/// code `0`, and the dequant is a single multiply. Affine parameters
/// are still supported by [`quantize`]/[`dequantize`] (and covered by
/// the round-trip property test); a kernel fed affine params asserts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Real-value step between adjacent codes (> 0).
    pub scale: f32,
    /// Code that represents real 0.
    pub zero_point: i32,
}

impl QuantParams {
    /// Symmetric parameters covering `[-max_abs, max_abs]`
    /// (`zero_point = 0`, `scale = max_abs / 127`). A zero or
    /// non-finite `max_abs` degrades to a tiny positive scale so the
    /// all-zero tensor round-trips exactly.
    pub fn symmetric(max_abs: f32) -> Self {
        let m = if max_abs.is_finite() && max_abs > 0.0 { max_abs } else { f32::MIN_POSITIVE };
        QuantParams { scale: m / 127.0, zero_point: 0 }
    }

    /// Affine parameters covering `[lo, hi]` across the full code range.
    pub fn affine(lo: f32, hi: f32) -> Self {
        assert!(lo <= hi, "affine range [{lo}, {hi}]");
        let span = (hi - lo).max(f32::MIN_POSITIVE);
        let scale = span / 255.0;
        let zero_point = (-128.0 - lo / scale).round() as i32;
        QuantParams { scale, zero_point }
    }

    /// Symmetric parameters for a tensor (dynamic per-tensor
    /// quantization: scale from the tensor's largest magnitude).
    pub fn for_tensor(x: &Tensor) -> Self {
        Self::symmetric(x.max_abs())
    }

    /// True when `zero_point == 0` (what the conv kernels require).
    pub fn is_symmetric(self) -> bool {
        self.zero_point == 0
    }

    /// Quantize one value (round to nearest, saturate to the i8 range).
    #[inline(always)]
    pub fn quantize_value(self, v: f32) -> i8 {
        // i64 keeps the sum well-defined even for saturated casts of
        // huge/non-finite inputs (f32→int casts saturate in Rust).
        let q = (v / self.scale).round() as i64 + self.zero_point as i64;
        q.clamp(i8::MIN as i64, i8::MAX as i64) as i8
    }

    /// Dequantize one code.
    #[inline(always)]
    pub fn dequantize_value(self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }
}

/// Weight-tensor quantization scales: one symmetric [`QuantParams`] for
/// the whole tensor, or one scale per output channel (`c_out` — the
/// leading weight dimension).
///
/// Per-channel scales cost nothing inside the integer kernels — the i32
/// accumulator `Σ x_code · w_code` is scale-agnostic — and only touch
/// the dequant (`raw · x_scale · w_scale[c_out]`), but they stop one
/// outlier filter from flattening every other channel's resolution,
/// which is where per-tensor int8 loses accuracy first.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightScales {
    /// One symmetric scale for the whole weight tensor.
    PerTensor(QuantParams),
    /// One symmetric scale per output channel (`zero_point = 0`
    /// implied; index = `c_out` row).
    PerChannel(Vec<f32>),
}

impl WeightScales {
    /// Per-tensor scales from symmetric `QuantParams`.
    pub fn per_tensor(q: QuantParams) -> Self {
        WeightScales::PerTensor(q)
    }

    /// The dequant scale for output channel `co`.
    #[inline(always)]
    pub fn scale(&self, co: usize) -> f32 {
        match self {
            WeightScales::PerTensor(q) => q.scale,
            WeightScales::PerChannel(s) => s[co],
        }
    }

    /// True when the scales are symmetric (what the int8 conv kernels
    /// require; per-channel scales are symmetric by construction).
    pub fn is_symmetric(&self) -> bool {
        match self {
            WeightScales::PerTensor(q) => q.is_symmetric(),
            WeightScales::PerChannel(_) => true,
        }
    }

    /// Number of channels for per-channel scales (`None` for
    /// per-tensor).
    pub fn channels(&self) -> Option<usize> {
        match self {
            WeightScales::PerTensor(_) => None,
            WeightScales::PerChannel(s) => Some(s.len()),
        }
    }
}

/// Quantize a weight tensor with **per-channel** symmetric scales: each
/// `c_out` row (leading dimension) gets its own
/// [`QuantParams::symmetric`] from that row's largest magnitude.
///
/// Returns the codes and the matching [`WeightScales::PerChannel`].
pub fn quantize_per_channel(w: &Tensor) -> (TensorT<i8>, WeightScales) {
    let c_out = w.dim(0);
    let inner = w.numel() / c_out;
    let ws = w.as_slice();
    let mut codes = vec![0i8; w.numel()];
    let mut scales = vec![0.0f32; c_out];
    for co in 0..c_out {
        let row = &ws[co * inner..(co + 1) * inner];
        let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let q = QuantParams::symmetric(max_abs);
        scales[co] = q.scale;
        for (c, &v) in codes[co * inner..(co + 1) * inner].iter_mut().zip(row) {
            *c = q.quantize_value(v);
        }
    }
    (TensorT::from_vec(codes, w.dims()), WeightScales::PerChannel(scales))
}

/// Quantize an `f32` tensor to i8 codes under `q`.
pub fn quantize(x: &Tensor, q: QuantParams) -> TensorT<i8> {
    let data = x.as_slice().iter().map(|&v| q.quantize_value(v)).collect();
    TensorT::from_vec(data, x.dims())
}

/// Dequantize i8 codes back to `f32` under `q`.
pub fn dequantize(x: &TensorT<i8>, q: QuantParams) -> Tensor {
    let data = x.as_slice().iter().map(|&c| q.dequantize_value(c)).collect();
    Tensor::from_vec(data, x.dims())
}

/// Round an `f32` tensor to bfloat16 storage.
pub fn to_bf16(x: &Tensor) -> TensorT<Bf16> {
    let data = x.as_slice().iter().map(|&v| Bf16::from_f32(v)).collect();
    TensorT::from_vec(data, x.dims())
}

/// Widen a bfloat16 tensor to `f32` (exact).
pub fn from_bf16(x: &TensorT<Bf16>) -> Tensor {
    let data = x.as_slice().iter().map(|b| b.to_f32()).collect();
    Tensor::from_vec(data, x.dims())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_channel_scales_track_each_row() {
        // Channel 0 holds small values, channel 1 one large outlier:
        // per-channel quantization must keep full resolution on row 0.
        let w = Tensor::from_vec(vec![0.1, -0.05, 100.0, 50.0], &[2, 2]);
        let (codes, ws) = quantize_per_channel(&w);
        assert_eq!(ws.channels(), Some(2));
        assert!(ws.is_symmetric());
        // Row 0 codes are quantized against 0.1, not 100.0.
        assert_eq!(codes.as_slice()[0], 127);
        assert_eq!(codes.as_slice()[2], 127);
        // Dequantizing row by row recovers the values within one step.
        for co in 0..2 {
            for i in 0..2 {
                let back = codes.as_slice()[co * 2 + i] as f32 * ws.scale(co);
                let want = w.as_slice()[co * 2 + i];
                assert!((back - want).abs() <= ws.scale(co), "co={co} i={i}");
            }
        }
    }

    #[test]
    fn per_tensor_weight_scales_match_quant_params() {
        let q = QuantParams::symmetric(2.0);
        let ws = WeightScales::per_tensor(q);
        assert_eq!(ws.scale(0), q.scale);
        assert_eq!(ws.scale(7), q.scale);
        assert_eq!(ws.channels(), None);
    }

    #[test]
    fn per_channel_matches_per_row_symmetric_quant() {
        let w = Tensor::randn(&[3, 8], 77);
        let (codes, ws) = quantize_per_channel(&w);
        for co in 0..3 {
            let row: Vec<f32> = w.as_slice()[co * 8..(co + 1) * 8].to_vec();
            let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let q = QuantParams::symmetric(max_abs);
            assert_eq!(ws.scale(co), q.scale);
            for (i, &v) in row.iter().enumerate() {
                assert_eq!(codes.as_slice()[co * 8 + i], q.quantize_value(v));
            }
        }
    }

    #[test]
    fn dtype_names_roundtrip() {
        for d in Dtype::ALL {
            assert_eq!(Dtype::parse(d.name()), Some(d));
        }
        assert_eq!(Dtype::parse("f64"), None);
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::I8.bytes(), 1);
        assert!(!Dtype::SERVING.contains(&Dtype::I32));
    }

    #[test]
    fn bf16_roundtrip_exact_for_representables() {
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625, 3.0e38, -1.0e-30] {
            let b = Bf16::from_f32(v);
            let back = b.to_f32();
            // Representable values (8 mantissa-bit ladder) are exact.
            assert_eq!(Bf16::from_f32(back).to_f32(), back);
            // And the round is within half a ulp (2^-8 relative).
            assert!((back - v).abs() <= v.abs() / 256.0 + f32::MIN_POSITIVE, "{v} -> {back}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between 1.0 and the next bf16;
        // nearest-even rounds down to 1.0.
        let v = 1.0 + 1.0 / 256.0;
        assert_eq!(Bf16::from_f32(v).to_f32(), 1.0);
        // A hair above the halfway point rounds up.
        let up = 1.0 + 1.5 / 256.0;
        assert!(Bf16::from_f32(up).to_f32() > 1.0);
    }

    #[test]
    fn bf16_specials() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        // Rounding at the top of the finite range may overflow to inf —
        // the IEEE behaviour — but must never panic.
        let _ = Bf16::from_f32(f32::MAX);
    }

    #[test]
    fn symmetric_params_cover_range() {
        let q = QuantParams::symmetric(2.54);
        assert!(q.is_symmetric());
        assert_eq!(q.quantize_value(2.54), 127);
        assert_eq!(q.quantize_value(-2.54), -127);
        assert_eq!(q.quantize_value(0.0), 0);
        // Saturation beyond the covered range.
        assert_eq!(q.quantize_value(100.0), 127);
        assert_eq!(q.quantize_value(-100.0), -128);
    }

    #[test]
    fn affine_params_place_zero_point() {
        let q = QuantParams::affine(-1.0, 3.0);
        assert!(!q.is_symmetric());
        // lo maps to (about) the bottom code, hi to (about) the top.
        assert!(q.quantize_value(-1.0) <= -127);
        assert!(q.quantize_value(3.0) >= 126);
        // Round-trip error within half a step everywhere in range.
        for i in 0..=40 {
            let v = -1.0 + 4.0 * i as f32 / 40.0;
            let r = q.dequantize_value(q.quantize_value(v));
            assert!((r - v).abs() <= q.scale / 2.0 + 1e-6, "{v} -> {r}");
        }
    }

    #[test]
    fn zero_tensor_roundtrips_exactly() {
        let x = Tensor::zeros(&[2, 3]);
        let q = QuantParams::for_tensor(&x);
        assert_eq!(dequantize(&quantize(&x, q), q).as_slice(), x.as_slice());
    }

    #[test]
    fn tensor_quantize_dequantize_close() {
        let x = Tensor::randn(&[4, 9], 3);
        let q = QuantParams::for_tensor(&x);
        let back = dequantize(&quantize(&x, q), q);
        assert!(x.max_abs_diff(&back) <= q.scale / 2.0 + 1e-6);
    }

    #[test]
    fn tensor_bf16_roundtrip_close() {
        let x = Tensor::randn(&[3, 7], 4);
        let back = from_bf16(&to_bf16(&x));
        assert!(x.max_abs_diff(&back) <= x.max_abs() / 256.0);
    }

    #[test]
    fn element_raw_code_conversions() {
        assert_eq!(<i8 as Element>::from_f32(3.6), 4);
        assert_eq!(<i8 as Element>::from_f32(300.0), 127, "saturates");
        assert_eq!(<i8 as Element>::from_f32(-300.0), -128);
        assert_eq!(<f32 as Element>::from_f32(1.5), 1.5);
        assert_eq!(<i32 as Element>::DTYPE, Dtype::I32);
        assert_eq!(Bf16::from_f32(1.0).to_f32(), 1.0);
    }
}
