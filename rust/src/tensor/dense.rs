//! The dense row-major tensor, generic over its [`Element`] type.

use super::element::Element;
use super::rng::XorShiftRng;

/// A contiguous row-major tensor of arbitrary rank, generic over the
/// storage element `E` (see [`Element`]).
///
/// [`Tensor`] (= `TensorT<f32>`) is the default instantiation every
/// pre-existing API keeps using; `TensorT<i8>` carries quantized codes
/// (with a per-tensor [`super::QuantParams`] alongside),
/// `TensorT<`[`super::Bf16`]`>` carries bfloat16 storage, and
/// `TensorT<i32>` carries the int8 kernels' raw accumulators.
///
/// Images use the NCHW convention `[batch, channels, height, width]`;
/// convolution weights use `[c_out, c_in, kh, kw]`; 1-D signals use
/// `[len]` or `[channels, len]`.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorT<E: Element> {
    data: Vec<E>,
    dims: Vec<usize>,
}

/// The default `f32` tensor (the pre-refactor `Tensor`, unchanged
/// behaviour bit for bit).
pub type Tensor = TensorT<f32>;

impl<E: Element> TensorT<E> {
    /// All-zero tensor of the given shape (`E::default()` is the
    /// additive zero for every element type).
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorT { data: vec![E::default(); n], dims: dims.to_vec() }
    }

    /// Tensor filled with `v`.
    pub fn full(dims: &[usize], v: E) -> Self {
        let n: usize = dims.iter().product();
        TensorT { data: vec![v; n], dims: dims.to_vec() }
    }

    /// Wrap an existing buffer. `data.len()` must equal the shape product.
    ///
    /// # Panics
    /// On length/shape mismatch.
    pub fn from_vec(data: Vec<E>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "from_vec: {} values for shape {:?}", data.len(), dims);
        TensorT { data, dims: dims.to_vec() }
    }

    /// Shape.
    #[inline]
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count.
    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Size of dimension `d`.
    #[inline]
    pub fn dim(&self, d: usize) -> usize {
        self.dims[d]
    }

    /// Row-major strides (in elements).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.dims.len()];
        for d in (0..self.dims.len().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.dims[d + 1];
        }
        s
    }

    /// Flat data view.
    #[inline]
    pub fn as_slice(&self) -> &[E] {
        &self.data
    }

    /// Mutable flat data view.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [E] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<E> {
        self.data
    }

    /// Flat offset of NCHW index `(n, c, h, w)`; tensor must be rank 4.
    #[inline]
    pub fn offset4(&self, n: usize, c: usize, h: usize, w: usize) -> usize {
        debug_assert_eq!(self.dims.len(), 4);
        ((n * self.dims[1] + c) * self.dims[2] + h) * self.dims[3] + w
    }

    /// Element at NCHW index.
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> E {
        self.data[self.offset4(n, c, h, w)]
    }

    /// Mutable element at NCHW index.
    #[inline]
    pub fn at4_mut(&mut self, n: usize, c: usize, h: usize, w: usize) -> &mut E {
        let o = self.offset4(n, c, h, w);
        &mut self.data[o]
    }

    /// The `(n, c)` image plane as a contiguous `[h * w]` slice (rank 4).
    #[inline]
    pub fn plane(&self, n: usize, c: usize) -> &[E] {
        let hw = self.dims[2] * self.dims[3];
        let start = (n * self.dims[1] + c) * hw;
        &self.data[start..start + hw]
    }

    /// Mutable `(n, c)` image plane (rank 4).
    #[inline]
    pub fn plane_mut(&mut self, n: usize, c: usize) -> &mut [E] {
        let hw = self.dims[2] * self.dims[3];
        let start = (n * self.dims[1] + c) * hw;
        &mut self.data[start..start + hw]
    }

    /// Reinterpret with a new shape of equal element count.
    ///
    /// # Panics
    /// If the products differ.
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.dims, dims);
        self.dims = dims.to_vec();
        self
    }

    /// Convert every element through its [`Element::to_f32`] widening —
    /// **raw** for `i8` tensors (codes, not dequantized reals; use
    /// [`super::dequantize`] for those), exact for `f32`/bf16/`i32`.
    pub fn widen_f32(&self) -> Tensor {
        TensorT {
            data: self.data.iter().map(|x| x.to_f32()).collect(),
            dims: self.dims.clone(),
        }
    }
}

impl Tensor {
    /// Standard-normal random tensor, deterministic in `seed`.
    pub fn randn(dims: &[usize], seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let n: usize = dims.iter().product();
        let data = (0..n).map(|_| rng.gauss()).collect();
        TensorT { data, dims: dims.to_vec() }
    }

    /// Uniform random tensor in `[lo, hi)`, deterministic in `seed`.
    pub fn rand_uniform(dims: &[usize], lo: f32, hi: f32, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let n: usize = dims.iter().product();
        let data = (0..n).map(|_| rng.uniform(lo, hi)).collect();
        TensorT { data, dims: dims.to_vec() }
    }

    /// Tensor whose flat element `i` is `i as f32` — handy in tests.
    pub fn iota(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorT { data: (0..n).map(|i| i as f32).collect(), dims: dims.to_vec() }
    }

    /// Element-wise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        TensorT {
            data: self.data.iter().map(|&x| f(x)).collect(),
            dims: self.dims.clone(),
        }
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Largest absolute difference against `other` (shapes must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims, other.dims, "max_abs_diff shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// True when every element matches `other` within `atol + rtol·|b|`
    /// (with `rtol` fixed at `1e-5`), the numpy `allclose` convention.
    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        if self.dims != other.dims {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(&a, &b)| (a - b).abs() <= atol + 1e-5 * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_full_shapes() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.dims(), &[2, 3, 4]);
        let f = Tensor::full(&[5], 2.5);
        assert!(f.as_slice().iter().all(|&x| x == 2.5));
    }

    #[test]
    #[should_panic(expected = "from_vec")]
    fn from_vec_checks_len() {
        let _ = Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.strides(), vec![60, 20, 5, 1]);
    }

    #[test]
    fn offset4_matches_strides() {
        let t = Tensor::iota(&[2, 3, 4, 5]);
        assert_eq!(t.at4(1, 2, 3, 4), (60 + 40 + 15 + 4) as f32);
    }

    #[test]
    fn plane_is_contiguous_hw() {
        let t = Tensor::iota(&[2, 3, 2, 2]);
        let p = t.plane(1, 2);
        assert_eq!(p, &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn plane_mut_writes_through() {
        let mut t = Tensor::zeros(&[1, 2, 2, 2]);
        t.plane_mut(0, 1)[3] = 9.0;
        assert_eq!(t.at4(0, 1, 1, 1), 9.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::iota(&[2, 6]).reshape(&[3, 4]);
        assert_eq!(t.dims(), &[3, 4]);
        assert_eq!(t.as_slice()[7], 7.0);
    }

    #[test]
    fn allclose_tolerates_small_error() {
        let a = Tensor::full(&[4], 1.0);
        let mut b = a.clone();
        b.as_mut_slice()[0] = 1.0 + 1e-7;
        assert!(a.allclose(&b, 1e-6));
        b.as_mut_slice()[0] = 1.1;
        assert!(!a.allclose(&b, 1e-6));
    }

    #[test]
    fn allclose_shape_mismatch_is_false() {
        assert!(!Tensor::zeros(&[2]).allclose(&Tensor::zeros(&[3]), 1.0));
    }

    #[test]
    fn randn_deterministic() {
        let a = Tensor::randn(&[10], 9);
        let b = Tensor::randn(&[10], 9);
        assert_eq!(a, b);
    }

    #[test]
    fn generic_tensors_hold_other_dtypes() {
        use crate::tensor::Bf16;
        let q = TensorT::<i8>::from_vec(vec![-3, 0, 7, 127], &[2, 2]);
        assert_eq!(q.as_slice()[3], 127);
        assert_eq!(q.widen_f32().as_slice(), &[-3.0, 0.0, 7.0, 127.0]);
        let z = TensorT::<i32>::zeros(&[3]);
        assert!(z.as_slice().iter().all(|&v| v == 0));
        let b = TensorT::<Bf16>::full(&[2], Bf16::from_f32(1.5));
        assert_eq!(b.widen_f32().as_slice(), &[1.5, 1.5]);
        let r = TensorT::<i8>::from_vec(vec![1, 2, 3, 4, 5, 6], &[2, 3]).reshape(&[3, 2]);
        assert_eq!(r.dims(), &[3, 2]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(vec![1.0, -4.0], &[2]);
        let b = Tensor::from_vec(vec![2.0, -1.0], &[2]);
        assert_eq!(a.max_abs_diff(&b), 3.0);
        assert_eq!(a.max_abs(), 4.0);
    }
}
