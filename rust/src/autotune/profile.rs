//! The cached per-machine dispatch profile: a crossover table mapping
//! `(filter-width bucket, thread count)` to the measured-fastest
//! convolution algorithm and row-kernel family.
//!
//! ## `profile.json` schema (version 3)
//!
//! [`DispatchProfile::save`] writes — and [`DispatchProfile::load`]
//! parses, via [`crate::runtime::json`] — a single JSON object:
//!
//! ```json
//! {
//!   "version": 3,
//!   "lanes": 16,
//!   "entries": [
//!     {"k": 3,  "threads": 1, "dtype": "f32", "isa": "avx2",   "algo": "sliding", "slide": "custom",   "gflops": 11.2},
//!     {"k": 17, "threads": 8, "dtype": "f32", "isa": "scalar", "algo": "sliding", "slide": "compound", "gflops": 64.0},
//!     {"k": 33, "threads": 8, "dtype": "i8",  "isa": "avx2",   "algo": "gemm",    "slide": "compound", "gflops": 41.5}
//!   ]
//! }
//! ```
//!
//! * `version` — schema version. `3` is current; `2`, `1` and a missing
//!   `version` (the pre-versioning format) load **backward
//!   compatibly** — a v1/versionless entry gets `dtype: "f32"`, and any
//!   entry without an `isa` field gets `isa: "scalar"` — so an old
//!   cache keeps steering dispatch instead of degrading to the paper
//!   policy with a warning. Anything else is rejected.
//! * `lanes` — [`crate::simd::LANES`] of the build that measured the
//!   profile. A profile measured for a different hardware-vector width
//!   describes a different machine, so a mismatch is rejected at load.
//! * `entries[].k` / `entries[].threads` — the measured bucket. Lookups
//!   restrict to the queried dtype's entries, prefer buckets measured
//!   at the queried ISA level, and minimise `(k distance, threads
//!   distance)` lexicographically over them, resolving exact ties
//!   toward the smaller bucket (see [`DispatchProfile::choice_at`]).
//! * `entries[].dtype` — element type this bucket was measured at
//!   (`"f32"`, `"bf16"`, `"i8"`); defaults to `"f32"` when absent.
//! * `entries[].isa` — instruction-set level this bucket was measured
//!   at (`"scalar"`, `"avx2"`, `"avx512"`, `"neon"`); defaults to
//!   `"scalar"` when absent (everything a pre-v3 profile measured ran
//!   the portable kernels).
//! * `entries[].algo` — conv-level winner: `"direct"`, `"gemm"` or
//!   `"sliding"`.
//! * `entries[].slide` — fastest sliding row-kernel family at this
//!   bucket: `"custom"`, `"generic"` or `"compound"` (recorded even when
//!   `algo` is not `"sliding"`, so forced-sliding callers still dispatch
//!   tuned rows; the `_q8`/`_bf16` row kernels are width-universal, so
//!   the family only steers f32 rows).
//! * `entries[].gflops` — the winner's measured throughput, for the
//!   record; not consulted by dispatch.
//!
//! Any parse failure, schema violation or unreadable file makes
//! [`DispatchProfile::load`] return an `Err`;
//! [`DispatchProfile::load_or_paper`] turns that into a warning plus the
//! paper-policy fallback, so a corrupt cache can never take serving down.

use crate::error::{bail, Context, Result};
use crate::kernels::rowconv::{RowKernel, COMPOUND_MAX_K};
use crate::runtime::json::Json;
use crate::simd::{IsaLevel, LANES};
use crate::tensor::Dtype;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Conv-level dispatch choice a profile entry records — deliberately
/// *not* [`crate::kernels::ConvAlgo`]: a tuned lookup must resolve to a
/// concrete kernel, never back to `Tuned` (no recursion) and never to an
/// under-specified auto policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunedAlgo {
    /// Naïve direct convolution.
    Direct,
    /// `im2col` + blocked GEMM.
    Gemm,
    /// Sliding Window, rows chosen by the entry's [`RowKernel`].
    Sliding,
}

impl TunedAlgo {
    /// All choices, in report order.
    pub const ALL: [TunedAlgo; 3] = [TunedAlgo::Direct, TunedAlgo::Gemm, TunedAlgo::Sliding];

    /// Stable name used in `profile.json`.
    pub fn name(self) -> &'static str {
        match self {
            TunedAlgo::Direct => "direct",
            TunedAlgo::Gemm => "gemm",
            TunedAlgo::Sliding => "sliding",
        }
    }

    /// Parse a stable name (inverse of [`TunedAlgo::name`]).
    pub fn parse(s: &str) -> Option<TunedAlgo> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }
}

/// One measured crossover-table row: the winners at a
/// `(filter width, thread count)` bucket.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProfileEntry {
    /// Filter width this bucket was measured at.
    pub k: usize,
    /// Worker-thread count this bucket was measured at.
    pub threads: usize,
    /// Element type this bucket was measured at (profiles loaded from
    /// the version-1 / versionless schema are f32-only).
    pub dtype: Dtype,
    /// Instruction-set level this bucket was measured at (profiles
    /// loaded from a pre-version-3 schema are scalar-only: everything
    /// they measured ran the portable kernels).
    pub isa: IsaLevel,
    /// Conv-level winner.
    pub algo: TunedAlgo,
    /// Fastest sliding row-kernel family at this bucket.
    pub slide: RowKernel,
    /// The winner's throughput when measured, GFLOP/s (recorded for the
    /// report; dispatch never reads it).
    pub gflops: f64,
}

/// A per-machine dispatch profile: the distilled crossover table the
/// autotuner measures (see [`crate::autotune::autotune`]), cached as
/// `profile.json` so serving never re-measures.
///
/// An **empty** profile is the paper's hard-coded §2 policy: every
/// lookup falls back to custom-3/5 → generic ≤ 17 → compound, with the
/// sliding algorithm at conv level — exactly what dispatch did before
/// this subsystem existed. [`DispatchProfile::is_paper_policy`] tells
/// the two apart.
///
/// # Examples
///
/// ```
/// use swconv::autotune::{DispatchProfile, TunedAlgo};
/// use swconv::kernels::rowconv::RowKernel;
///
/// // No profile on disk → the paper policy.
/// let paper = DispatchProfile::paper_policy();
/// assert!(paper.is_paper_policy());
/// assert_eq!(paper.choice(5, 1), (TunedAlgo::Sliding, RowKernel::Custom));
/// assert_eq!(paper.choice(9, 1), (TunedAlgo::Sliding, RowKernel::Generic));
/// assert_eq!(paper.choice(33, 1), (TunedAlgo::Sliding, RowKernel::Compound));
/// ```
#[derive(Clone, Debug, PartialEq, Default)]
pub struct DispatchProfile {
    entries: Vec<ProfileEntry>,
}

/// Where the CLI caches the machine's profile:
/// `target/autotune/profile.json` (relative to the working directory,
/// like the `target/reports/BENCH_*.json` artifacts).
pub fn default_profile_path() -> PathBuf {
    PathBuf::from("target/autotune/profile.json")
}

impl DispatchProfile {
    /// The empty profile — every lookup answers with the paper's §2
    /// policy.
    pub fn paper_policy() -> Self {
        DispatchProfile { entries: Vec::new() }
    }

    /// Build from measured entries (the autotuner's constructor).
    pub fn from_entries(entries: Vec<ProfileEntry>) -> Self {
        DispatchProfile { entries }
    }

    /// The crossover table, as measured.
    pub fn entries(&self) -> &[ProfileEntry] {
        &self.entries
    }

    /// True when the table is empty and every lookup falls back to the
    /// paper policy.
    pub fn is_paper_policy(&self) -> bool {
        self.entries.is_empty()
    }

    /// The tuned `(conv-level algorithm, row-kernel family)` for filter
    /// width `k` at `threads` worker threads, for `f32` dispatch —
    /// shorthand for [`DispatchProfile::choice_for`] with
    /// [`Dtype::F32`].
    pub fn choice(&self, k: usize, threads: usize) -> (TunedAlgo, RowKernel) {
        self.choice_for(k, threads, Dtype::F32)
    }

    /// [`DispatchProfile::choice_at`] at the process-wide effective
    /// instruction-set level ([`IsaLevel::effective`]).
    pub fn choice_for(&self, k: usize, threads: usize, dtype: Dtype) -> (TunedAlgo, RowKernel) {
        self.choice_at(k, threads, dtype, IsaLevel::effective())
    }

    /// The tuned `(conv-level algorithm, row-kernel family)` for filter
    /// width `k` at `threads` worker threads, element type `dtype` and
    /// instruction-set level `isa`.
    ///
    /// Nearest-bucket lookup over the entries **measured at this
    /// dtype**, minimising `(isa mismatch, k distance, thread
    /// distance)` lexicographically — a bucket measured at the queried
    /// ISA level always beats an off-level one, but when this level was
    /// never measured (say, a pre-v3 scalar-only cache running on an
    /// AVX2 machine) the same-dtype buckets still steer dispatch rather
    /// than falling to the paper policy: the crossover *shape* is far
    /// more dtype- than ISA-sensitive. Equal distances resolve toward
    /// the smaller `k`, then the smaller `threads`, so ties are
    /// deterministic. The answer is clamped so it is always *legal*:
    /// the row family is re-clamped through [`RowKernel::legal_for`],
    /// and a sliding choice for a width beyond the compound kernel's
    /// reach degrades to [`TunedAlgo::Direct`] (mirroring the auto
    /// policy's direct fallback; the clamp only matters for f32 rows —
    /// the `_q8`/`_bf16` kernels are width-universal). An empty profile
    /// — or one with no buckets at this dtype (e.g. a version-1
    /// f32-only cache queried for `I8`) — answers with the paper policy
    /// rather than borrowing another dtype's crossovers.
    pub fn choice_at(
        &self,
        k: usize,
        threads: usize,
        dtype: Dtype,
        isa: IsaLevel,
    ) -> (TunedAlgo, RowKernel) {
        let k = k.max(1);
        let nearest = self.nearest(k, threads, dtype, isa);
        let clamped = k.min(COMPOUND_MAX_K);
        let (algo, slide) = match nearest {
            Some(e) => (e.algo, e.slide.legal_for(clamped)),
            None => (TunedAlgo::Sliding, RowKernel::paper_policy(clamped)),
        };
        if algo == TunedAlgo::Sliding && k > COMPOUND_MAX_K {
            (TunedAlgo::Direct, slide)
        } else {
            (algo, slide)
        }
    }

    /// The tuned row-kernel family for width `k` at `threads` threads
    /// (the [`DispatchProfile::choice`] slide component).
    pub fn row_kernel(&self, k: usize, threads: usize) -> RowKernel {
        self.choice(k, threads).1
    }

    /// The nearest measured bucket for the query, same dtype only —
    /// the lexicographic `(isa mismatch, k distance, thread distance,
    /// smaller k, smaller threads)` order [`DispatchProfile::choice_at`]
    /// documents.
    fn nearest(
        &self,
        k: usize,
        threads: usize,
        dtype: Dtype,
        isa: IsaLevel,
    ) -> Option<ProfileEntry> {
        self.entries
            .iter()
            .filter(|e| e.dtype == dtype)
            .min_by_key(|e| {
                let dk = e.k.abs_diff(k);
                let dt = e.threads.abs_diff(threads);
                (e.isa != isa, dk, dt, e.k, e.threads)
            })
            .copied()
    }

    /// The nearest measured bucket's winner and its recorded GFLOP/s,
    /// for the whole-model planner's throughput prediction
    /// ([`crate::graph::planner`]): dispatch itself never reads
    /// `gflops`, but the planner needs an absolute speed anchor per
    /// `(k, threads, dtype)` to compare layer-wise candidates. `None`
    /// when no bucket at this dtype was measured (paper-policy
    /// fallback territory).
    pub fn measured_at(
        &self,
        k: usize,
        threads: usize,
        dtype: Dtype,
        isa: IsaLevel,
    ) -> Option<(TunedAlgo, f64)> {
        self.nearest(k.max(1), threads, dtype, isa).map(|e| (e.algo, e.gflops))
    }

    /// Serialize to `path` (schema at the
    /// [module level](crate::autotune::profile)).
    /// Parent directories are created. Written entries round-trip
    /// exactly: floats use Rust's shortest-round-trip `Display`.
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"version\": 3,")?;
        writeln!(f, "  \"lanes\": {LANES},")?;
        writeln!(f, "  \"entries\": [")?;
        for (i, e) in self.entries.iter().enumerate() {
            let sep = if i + 1 == self.entries.len() { "" } else { "," };
            // Non-finite throughput would not be valid JSON; it can only
            // mean a broken measurement, so record it as 0.
            let gflops = if e.gflops.is_finite() { e.gflops } else { 0.0 };
            writeln!(
                f,
                "    {{\"k\": {}, \"threads\": {}, \"dtype\": \"{}\", \"isa\": \"{}\", \
                 \"algo\": \"{}\", \"slide\": \"{}\", \"gflops\": {}}}{sep}",
                e.k,
                e.threads,
                e.dtype.name(),
                e.isa.name(),
                e.algo.name(),
                e.slide.name(),
                gflops
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }

    /// Load and validate a profile from `path`. Every failure mode — an
    /// unreadable file, malformed JSON, a wrong `version`, a `lanes`
    /// mismatch, or an entry with unknown names / zero buckets — is an
    /// `Err`, never a panic.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::load_versioned(path).map(|(p, _)| p)
    }

    /// [`DispatchProfile::load`] that also reports the on-disk **schema
    /// version** (1–3; a versionless pre-versioning cache reports 1).
    /// Old versions load backward compatibly and silently, which makes a
    /// degraded v1/v2 cache indistinguishable from a fresh v3 one unless
    /// the caller surfaces the version — the `autotune` CLI prints it
    /// for exactly that reason.
    pub fn load_versioned(path: impl AsRef<Path>) -> Result<(Self, usize)> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing profile {}", path.display()))?;
        Self::from_json_versioned(&j)
    }

    /// Parse an already-loaded JSON document (schema at the
    /// [module level](crate::autotune::profile)).
    pub fn from_json(j: &Json) -> Result<Self> {
        Self::from_json_versioned(j).map(|(p, _)| p)
    }

    /// [`DispatchProfile::from_json`] returning the document's schema
    /// version alongside the profile.
    pub fn from_json_versioned(j: &Json) -> Result<(Self, usize)> {
        // Versionless documents are the pre-versioning format: accept
        // them — like explicit version 1 — as f32-only (the satellite
        // promise: an old cache keeps steering f32 dispatch instead of
        // degrading to the paper policy with a warning).
        let version = match j.get("version") {
            None => 1,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| crate::anyhow!("profile 'version' not an integer"))?,
        };
        if !(1..=3).contains(&version) {
            bail!("profile version {version} unsupported (want 1 to 3)");
        }
        let lanes = j
            .get("lanes")
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::anyhow!("profile missing 'lanes'"))?;
        if lanes != LANES {
            bail!("profile measured for {lanes}-lane vectors, this build has {LANES}");
        }
        let arr = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| crate::anyhow!("profile missing 'entries' array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let field = |name: &str| {
                e.get(name)
                    .ok_or_else(|| crate::anyhow!("entry {i}: missing '{name}'"))
            };
            let k = field("k")?.as_usize().unwrap_or(0);
            let threads = field("threads")?.as_usize().unwrap_or(0);
            if k == 0 || threads == 0 {
                bail!("entry {i}: k and threads must be >= 1");
            }
            let algo_name = field("algo")?
                .as_str()
                .ok_or_else(|| crate::anyhow!("entry {i}: 'algo' not a string"))?;
            let algo = TunedAlgo::parse(algo_name)
                .ok_or_else(|| crate::anyhow!("entry {i}: unknown algo '{algo_name}'"))?;
            let slide_name = field("slide")?
                .as_str()
                .ok_or_else(|| crate::anyhow!("entry {i}: 'slide' not a string"))?;
            let slide = RowKernel::parse(slide_name)
                .ok_or_else(|| crate::anyhow!("entry {i}: unknown slide '{slide_name}'"))?;
            // The dtype dimension arrived with version 2; version-1 (and
            // versionless) entries are f32 buckets, and a v2 entry
            // without the field defaults the same way.
            let dtype = match e.get("dtype") {
                None => Dtype::F32,
                Some(d) => {
                    let name = d
                        .as_str()
                        .ok_or_else(|| crate::anyhow!("entry {i}: 'dtype' not a string"))?;
                    Dtype::parse(name)
                        .ok_or_else(|| crate::anyhow!("entry {i}: unknown dtype '{name}'"))?
                }
            };
            // The ISA dimension arrived with version 3; everything a
            // pre-v3 profile measured ran the portable kernels, so
            // entries without the field load as scalar buckets —
            // silently, never with a warning.
            let isa = match e.get("isa") {
                None => IsaLevel::Scalar,
                Some(d) => {
                    let name = d
                        .as_str()
                        .ok_or_else(|| crate::anyhow!("entry {i}: 'isa' not a string"))?;
                    IsaLevel::parse(name)
                        .ok_or_else(|| crate::anyhow!("entry {i}: unknown isa '{name}'"))?
                }
            };
            let gflops = field("gflops")?.as_f64().unwrap_or(0.0);
            entries.push(ProfileEntry { k, threads, dtype, isa, algo, slide, gflops });
        }
        Ok((DispatchProfile { entries }, version))
    }

    /// [`DispatchProfile::load`], degraded to the paper policy on any
    /// failure: a missing cache is silent (first run), everything else
    /// warns on stderr. Serving therefore *cannot* be taken down by a
    /// corrupt or truncated `profile.json` — it just dispatches like the
    /// paper again.
    pub fn load_or_paper(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref();
        if !path.exists() {
            return Self::paper_policy();
        }
        match Self::load(path) {
            Ok(p) => p,
            Err(e) => {
                eprintln!(
                    "warning: ignoring dispatch profile {}: {e}; \
                     falling back to the paper's k=17 policy",
                    path.display()
                );
                Self::paper_policy()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DispatchProfile {
        DispatchProfile::from_entries(vec![
            ProfileEntry {
                k: 3,
                threads: 1,
                dtype: Dtype::F32,
                isa: IsaLevel::Scalar,
                algo: TunedAlgo::Sliding,
                slide: RowKernel::Custom,
                gflops: 10.5,
            },
            ProfileEntry {
                k: 9,
                threads: 1,
                dtype: Dtype::F32,
                isa: IsaLevel::Scalar,
                algo: TunedAlgo::Sliding,
                slide: RowKernel::Compound,
                gflops: 9.25,
            },
            ProfileEntry {
                k: 9,
                threads: 8,
                dtype: Dtype::F32,
                isa: IsaLevel::Scalar,
                algo: TunedAlgo::Gemm,
                slide: RowKernel::Generic,
                gflops: 40.0,
            },
            ProfileEntry {
                k: 33,
                threads: 1,
                dtype: Dtype::F32,
                isa: IsaLevel::Scalar,
                algo: TunedAlgo::Direct,
                slide: RowKernel::Compound,
                gflops: 2.0,
            },
            ProfileEntry {
                k: 9,
                threads: 1,
                dtype: Dtype::I8,
                isa: IsaLevel::Scalar,
                algo: TunedAlgo::Gemm,
                slide: RowKernel::Generic,
                gflops: 55.0,
            },
        ])
    }

    #[test]
    fn empty_profile_is_paper_policy() {
        let p = DispatchProfile::paper_policy();
        assert!(p.is_paper_policy());
        assert_eq!(p.choice(3, 4), (TunedAlgo::Sliding, RowKernel::Custom));
        assert_eq!(p.choice(17, 1), (TunedAlgo::Sliding, RowKernel::Generic));
        assert_eq!(p.choice(18, 1), (TunedAlgo::Sliding, RowKernel::Compound));
        // Beyond the compound reach the conv level degrades to direct,
        // mirroring SlideVariant::Auto's fallback.
        let big = crate::kernels::rowconv::COMPOUND_MAX_K + 1;
        assert_eq!(p.choice(big, 1).0, TunedAlgo::Direct);
    }

    #[test]
    fn nearest_bucket_lookup() {
        let p = sample();
        // Exact hits.
        assert_eq!(p.choice(3, 1), (TunedAlgo::Sliding, RowKernel::Custom));
        assert_eq!(p.choice(9, 8), (TunedAlgo::Gemm, RowKernel::Generic));
        // k between buckets: 4 is nearer 3 than 9.
        assert_eq!(p.choice(4, 1).0, TunedAlgo::Sliding);
        // k=6 ties 3 and 9 → smaller bucket wins (k=3, custom row) but
        // custom cannot evaluate 6, so the row clamps to paper policy.
        assert_eq!(p.choice(6, 1), (TunedAlgo::Sliding, RowKernel::Generic));
        // threads between buckets at k=9: 2 is nearer 1 than 8.
        assert_eq!(p.choice(9, 2).0, TunedAlgo::Sliding);
        assert_eq!(p.choice(9, 6).0, TunedAlgo::Gemm);
        // Far k snaps to the 33 bucket.
        assert_eq!(p.choice(40, 1).0, TunedAlgo::Direct);
    }

    #[test]
    fn lookup_clamps_illegal_rows() {
        // An entry claiming "generic" far beyond the generic reach must
        // never hand back the generic kernel.
        let p = DispatchProfile::from_entries(vec![ProfileEntry {
            k: 33,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Sliding,
            slide: RowKernel::Generic,
            gflops: 1.0,
        }]);
        assert_eq!(p.row_kernel(33, 1), RowKernel::Compound);
    }

    #[test]
    fn choice_at_prefers_the_queried_isa_but_still_steers_off_level() {
        // Two buckets at the same (k, threads, dtype), different ISA
        // levels disagreeing on the winner.
        let scalar = ProfileEntry {
            k: 9,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Gemm,
            slide: RowKernel::Generic,
            gflops: 4.0,
        };
        let avx2 = ProfileEntry { isa: IsaLevel::Avx2, algo: TunedAlgo::Sliding, ..scalar };
        let p = DispatchProfile::from_entries(vec![scalar, avx2]);
        // A matching-level bucket beats the off-level one, even when the
        // off-level bucket is nearer in (k, threads).
        assert_eq!(p.choice_at(9, 1, Dtype::F32, IsaLevel::Scalar).0, TunedAlgo::Gemm);
        assert_eq!(p.choice_at(9, 1, Dtype::F32, IsaLevel::Avx2).0, TunedAlgo::Sliding);
        // A level that was never measured still steers from the
        // same-dtype buckets instead of degrading to the paper policy;
        // the tie between the two off-level buckets is broken by the
        // deterministic (k, threads) order — both share it, so the
        // first in entry order of the min is irrelevant: min_by_key
        // keeps the earliest minimum, the scalar bucket.
        assert_eq!(p.choice_at(9, 1, Dtype::F32, IsaLevel::Neon).0, TunedAlgo::Gemm);
        // Scalar-only caches (every pre-v3 profile) steer an AVX2 ctx.
        let old = DispatchProfile::from_entries(vec![scalar]);
        assert_eq!(old.choice_at(9, 1, Dtype::F32, IsaLevel::Avx2).0, TunedAlgo::Gemm);
    }

    #[test]
    fn choice_restricts_to_the_queried_dtype() {
        let p = sample();
        // f32 lookup at k=9/t=1 sees the f32 bucket (sliding), not the
        // int8 one (gemm).
        assert_eq!(p.choice(9, 1).0, TunedAlgo::Sliding);
        assert_eq!(p.choice_for(9, 1, Dtype::I8).0, TunedAlgo::Gemm);
        // A dtype with no buckets answers with the paper policy instead
        // of borrowing another dtype's crossovers.
        assert_eq!(
            p.choice_for(9, 1, Dtype::Bf16),
            (TunedAlgo::Sliding, RowKernel::Generic)
        );
    }

    /// Bucket-lookup edges: the lookup is *total* — a query below the
    /// smallest measured bucket, above the largest, or against a
    /// single-entry profile always answers (snapping to the nearest
    /// bucket), never panics.
    #[test]
    fn choice_at_edges_below_above_and_single_entry() {
        let p = sample(); // f32 buckets at k = 3, 9, 33
        // k below the smallest bucket snaps to k=3's algo; the custom
        // row cannot evaluate width 1, so the row clamps legal.
        assert_eq!(p.choice(1, 1), (TunedAlgo::Sliding, RowKernel::Generic));
        assert_eq!(p.choice(2, 1).0, TunedAlgo::Sliding);
        // k above the largest bucket snaps to k=33 (direct), at any
        // thread count — including thread counts never measured.
        assert_eq!(p.choice(1000, 1).0, TunedAlgo::Direct);
        assert_eq!(p.choice(1000, 999).0, TunedAlgo::Direct);
        // k=0 is clamped to 1 rather than panicking on the distance math.
        assert_eq!(p.choice(0, 1), p.choice(1, 1));

        // A single-entry profile answers every query from that entry
        // (clamped legal), regardless of distance or direction.
        let single = DispatchProfile::from_entries(vec![ProfileEntry {
            k: 9,
            threads: 4,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Gemm,
            slide: RowKernel::Generic,
            gflops: 12.0,
        }]);
        for (k, threads) in [(1, 1), (9, 4), (500, 1), (9, 64), (COMPOUND_MAX_K + 40, 2)] {
            let (algo, slide) = single.choice(k, threads);
            assert_eq!(algo, TunedAlgo::Gemm, "k={k} t={threads}");
            assert_eq!(slide, RowKernel::Generic.legal_for(k.min(COMPOUND_MAX_K)));
        }
        // And the empty profile stays total too (paper policy).
        let empty = DispatchProfile::paper_policy();
        for k in [0usize, 1, 2, 17, 18, COMPOUND_MAX_K, COMPOUND_MAX_K + 1, 10_000] {
            let _ = empty.choice(k, 1); // must not panic
        }
    }

    #[test]
    fn measured_at_reports_the_nearest_winner_and_throughput() {
        let p = sample();
        assert_eq!(
            p.measured_at(3, 1, Dtype::F32, IsaLevel::Scalar),
            Some((TunedAlgo::Sliding, 10.5))
        );
        // Nearest-bucket semantics match choice_at's.
        assert_eq!(
            p.measured_at(40, 1, Dtype::F32, IsaLevel::Scalar),
            Some((TunedAlgo::Direct, 2.0))
        );
        assert_eq!(
            p.measured_at(9, 1, Dtype::I8, IsaLevel::Scalar),
            Some((TunedAlgo::Gemm, 55.0))
        );
        // No bucket at the dtype → None (planner falls to flat priors).
        assert_eq!(p.measured_at(9, 1, Dtype::Bf16, IsaLevel::Scalar), None);
        assert_eq!(
            DispatchProfile::paper_policy().measured_at(9, 1, Dtype::F32, IsaLevel::Scalar),
            None
        );
    }

    #[test]
    fn load_versioned_reports_the_schema_version() {
        let dir = std::env::temp_dir();
        let p = sample();
        let path = dir.join("swconv_profile_versioned.json");
        p.save(&path).unwrap();
        let (q, version) = DispatchProfile::load_versioned(&path).unwrap();
        assert_eq!(version, 3, "save writes the current schema");
        assert_eq!(p, q);
        let _ = std::fs::remove_file(&path);
        // A versionless document reports version 1.
        let versionless = format!(
            "{{\"lanes\": {LANES}, \"entries\": [\
             {{\"k\": 9, \"threads\": 1, \"algo\": \"gemm\", \"slide\": \"generic\", \
             \"gflops\": 4.0}}]}}"
        );
        std::fs::write(&path, versionless).unwrap();
        let (_, version) = DispatchProfile::load_versioned(&path).unwrap();
        assert_eq!(version, 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let p = sample();
        let path = std::env::temp_dir().join("swconv_profile_roundtrip.json");
        p.save(&path).unwrap();
        let q = DispatchProfile::load(&path).unwrap();
        assert_eq!(p, q, "profile must round-trip bit-exact through JSON");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_rejects_bad_documents() {
        let dir = std::env::temp_dir();
        let cases: [(&str, &str); 5] = [
            ("not json at all", "parse"),
            ("{\"version\": 99, \"lanes\": 16, \"entries\": []}", "version"),
            ("{\"version\": 1, \"entries\": []}", "lanes"),
            ("{\"version\": 1, \"lanes\": 9999, \"entries\": []}", "lane"),
            (
                "{\"version\": 1, \"lanes\": 16, \"entries\": [{\"k\": 3}]}",
                "entry",
            ),
        ];
        for (i, (doc, why)) in cases.iter().enumerate() {
            let path = dir.join(format!("swconv_profile_bad_{i}.json"));
            std::fs::write(&path, doc).unwrap();
            assert!(
                DispatchProfile::load(&path).is_err(),
                "case {i} ({why}) must be rejected"
            );
            // And the degraded loader answers with the paper policy.
            assert!(DispatchProfile::load_or_paper(&path).is_paper_policy());
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn old_and_versionless_profiles_load_as_f32_only() {
        let dir = std::env::temp_dir();
        let v1 = format!(
            "{{\"version\": 1, \"lanes\": {LANES}, \"entries\": [\
             {{\"k\": 9, \"threads\": 1, \"algo\": \"gemm\", \"slide\": \"generic\", \
             \"gflops\": 4.0}}]}}"
        );
        let versionless = format!(
            "{{\"lanes\": {LANES}, \"entries\": [\
             {{\"k\": 9, \"threads\": 1, \"algo\": \"gemm\", \"slide\": \"generic\", \
             \"gflops\": 4.0}}]}}"
        );
        for (name, doc) in [("v1", v1), ("versionless", versionless)] {
            let path = dir.join(format!("swconv_profile_compat_{name}.json"));
            std::fs::write(&path, doc).unwrap();
            let p = DispatchProfile::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!p.is_paper_policy(), "{name} must load its bucket, not degrade");
            assert_eq!(p.entries()[0].dtype, Dtype::F32, "{name} entries are f32-only");
            assert_eq!(p.entries()[0].isa, IsaLevel::Scalar, "{name} entries are scalar-only");
            // The f32 bucket steers f32 dispatch…
            assert_eq!(p.choice(9, 1).0, TunedAlgo::Gemm, "{name}");
            // …and is invisible to other dtypes.
            assert_eq!(p.choice_for(9, 1, Dtype::I8).0, TunedAlgo::Sliding, "{name}");
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn v2_profiles_load_as_scalar_only() {
        // A version-2 cache (dtype-aware, pre-ISA) loads silently with
        // every entry at the scalar level — and keeps steering dispatch
        // at any queried level.
        let doc = format!(
            "{{\"version\": 2, \"lanes\": {LANES}, \"entries\": [\
             {{\"k\": 9, \"threads\": 1, \"dtype\": \"i8\", \"algo\": \"gemm\", \
             \"slide\": \"generic\", \"gflops\": 4.0}}]}}"
        );
        let path = std::env::temp_dir().join("swconv_profile_compat_v2.json");
        std::fs::write(&path, doc).unwrap();
        let p = DispatchProfile::load(&path).unwrap();
        assert_eq!(p.entries()[0].isa, IsaLevel::Scalar);
        assert_eq!(p.entries()[0].dtype, Dtype::I8);
        for isa in IsaLevel::ALL {
            assert_eq!(p.choice_at(9, 1, Dtype::I8, isa).0, TunedAlgo::Gemm, "{isa}");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_or_paper_on_missing_file_is_silent_paper() {
        let p = DispatchProfile::load_or_paper("/nonexistent/swconv/profile.json");
        assert!(p.is_paper_policy());
    }

    #[test]
    fn names_roundtrip() {
        for a in TunedAlgo::ALL {
            assert_eq!(TunedAlgo::parse(a.name()), Some(a));
        }
        assert_eq!(TunedAlgo::parse("tuned"), None, "no recursion by construction");
    }
}
