//! The microbenchmark pass that fills a [`DispatchProfile`]: per
//! `(filter width, thread count)` bucket, race every convolution
//! implementation on a representative plane and record the winners.
//!
//! Reuses the harness' measurement loop ([`crate::harness::timing`]) and
//! the kernels' `*_ctx` entry points, so what is timed is exactly what
//! serving executes — same scratch arena, same thread fan-out.

use super::profile::{DispatchProfile, ProfileEntry, TunedAlgo};
use crate::exec::{available_threads, pool, CacheInfo, ExecCtx, WorkerPool};
use crate::graph::{tiling, TileMode};
use std::sync::Arc;
use crate::harness::report::{f3, Table};
use crate::harness::timing::bench_config;
use crate::harness::workload::ConvCase;
use crate::kernels::im2col::conv2d_im2col_q8_raw_ctx;
use crate::kernels::rowconv::{RowKernel, COMPOUND_MAX_K};
use crate::kernels::sliding2d::conv2d_sliding_q8_raw_ctx;
use crate::kernels::{conv2d_ctx, ConvAlgo};
use crate::nn::Model;
use crate::simd::IsaLevel;
use crate::tensor::{quantize, Dtype, QuantParams, Tensor};
use std::time::Duration;

/// What the autotuner measures: the representative workload geometry,
/// the `(k, threads)` grid, and how much timing effort to spend per
/// candidate.
#[derive(Clone, Debug)]
pub struct AutotuneOpts {
    /// Channels of the representative plane (in = out, the Fig. 1/2
    /// setup).
    pub c: usize,
    /// Spatial size of the representative plane (`hw × hw`).
    pub hw: usize,
    /// Filter widths to measure — the bucket centres of the resulting
    /// crossover table.
    pub ks: Vec<usize>,
    /// Thread counts to measure (each becomes a bucket dimension).
    pub threads: Vec<usize>,
    /// Timed samples per candidate (see
    /// [`crate::harness::timing::bench_config`]).
    pub samples: usize,
    /// Minimum time per sample.
    pub sample_target: Duration,
    /// Element type to measure: [`Dtype::F32`] races the five f32
    /// families; [`Dtype::I8`] races int8 sliding against the int8
    /// im2col+GEMM baseline and records `dtype: "i8"` buckets (what
    /// `conv2d_q8_ctx`'s tuned routing consults). Other dtypes have no
    /// kernel family split to tune and are rejected.
    pub dtype: Dtype,
    /// Print one progress line per bucket to stderr.
    pub verbose: bool,
}

impl Default for AutotuneOpts {
    /// The Fig. 1/2 plane (c=4, 64×64), every dispatch regime — custom
    /// (3/5), generic (≤17), the crossover (18) and the compound zigzag
    /// — at 1 thread and all hardware threads.
    fn default() -> Self {
        let all = available_threads();
        let mut threads = vec![1];
        if all > 1 {
            threads.push(all);
        }
        AutotuneOpts {
            c: 4,
            hw: 64,
            ks: vec![1, 2, 3, 4, 5, 7, 9, 11, 13, 15, 17, 18, 21, 25, 33, 49],
            threads,
            samples: 5,
            sample_target: Duration::from_millis(10),
            dtype: Dtype::F32,
            verbose: false,
        }
    }
}

impl AutotuneOpts {
    /// A deliberately tiny pass (small plane, few widths, one sample)
    /// for tests and smoke runs: completes in well under a second and
    /// still exercises every candidate family.
    pub fn quick() -> Self {
        AutotuneOpts {
            c: 1,
            hw: 16,
            ks: vec![3, 9, 19],
            threads: vec![1],
            samples: 1,
            sample_target: Duration::from_micros(500),
            dtype: Dtype::F32,
            verbose: false,
        }
    }

    /// [`AutotuneOpts::quick`] measuring the int8 kernel family.
    pub fn quick_i8() -> Self {
        AutotuneOpts { dtype: Dtype::I8, ..Self::quick() }
    }
}

/// The conv-level candidates raced at every bucket, and how each maps
/// into a profile entry. `Sliding` is the paper's auto policy, so at
/// k = 3/5 it *is* the custom-kernel candidate.
const CANDIDATES: [ConvAlgo; 5] = [
    ConvAlgo::Direct,
    ConvAlgo::Im2colGemm,
    ConvAlgo::Sliding,
    ConvAlgo::SlidingGeneric,
    ConvAlgo::SlidingCompound,
];

fn tuned_algo_of(algo: ConvAlgo) -> TunedAlgo {
    match algo {
        ConvAlgo::Direct => TunedAlgo::Direct,
        ConvAlgo::Im2colGemm => TunedAlgo::Gemm,
        _ => TunedAlgo::Sliding,
    }
}

fn row_kernel_of(algo: ConvAlgo, k: usize) -> RowKernel {
    match algo {
        ConvAlgo::SlidingGeneric => RowKernel::Generic,
        ConvAlgo::SlidingCompound => RowKernel::Compound,
        // The auto policy's family at this width.
        _ => RowKernel::paper_policy(k.min(COMPOUND_MAX_K)),
    }
}

/// Measure a dispatch profile: for every `(k, threads, isa)` bucket in
/// `opts` — the ISA dimension is every [`IsaLevel::available_levels`]
/// on this machine, each candidate ctx pinned to the level via
/// [`ExecCtx::with_isa`] — time each candidate of the opts' dtype on
/// the representative plane and distill the crossover table. Pure
/// measurement — callers
/// persist the result with [`DispatchProfile::save`] (the CLI caches it
/// at [`super::profile::default_profile_path`], merging per-dtype
/// passes into one cache). The contexts it measures on resolve their
/// worker pools exactly like serving contexts do, so cached crossovers
/// reflect the real (pooled by default) dispatch cost.
///
/// # Panics
/// If `opts.dtype` is neither `F32` nor `I8` — the other element types
/// have no kernel-family split to tune (the CLI rejects them earlier).
pub fn autotune(opts: &AutotuneOpts) -> DispatchProfile {
    assert!(
        matches!(opts.dtype, Dtype::F32 | Dtype::I8),
        "autotune measures f32 or i8 kernel families, not {}",
        opts.dtype.name()
    );
    let mut entries = Vec::new();
    let mut ks = opts.ks.clone();
    ks.sort_unstable();
    ks.dedup();
    let mut threads = opts.threads.clone();
    threads.sort_unstable();
    threads.dedup();

    for &t in &threads {
        let t = t.max(1);
        // One persistent pool per thread count, shared by every
        // candidate ctx at this `t`: measurements still run on the
        // pooled path serving uses, without re-paying a pool spawn/join
        // per (candidate, k) — the very overhead the pool retires.
        // `None` under global disablement, so `--no-pool` autotune
        // measures the scoped path it will serve with.
        let shared = if t > 1 && !pool::pooling_disabled() {
            Some(WorkerPool::new(t - 1))
        } else {
            None
        };
        for &k in &ks {
            if k == 0 {
                continue;
            }
            for &isa in &IsaLevel::available_levels() {
                let entry = match opts.dtype {
                    Dtype::I8 => measure_i8_bucket(opts, k, t, isa, shared.as_ref()),
                    _ => measure_f32_bucket(opts, k, t, isa, shared.as_ref()),
                };
                if opts.verbose {
                    eprintln!(
                        "autotune[{}]: k={k:<3} threads={t:<3} isa={:<6} -> {} / {} rows \
                         ({} GFLOP/s)",
                        opts.dtype.name(),
                        isa.name(),
                        entry.algo.name(),
                        entry.slide.name(),
                        f3(entry.gflops)
                    );
                }
                entries.push(entry);
            }
        }
    }
    DispatchProfile::from_entries(entries)
}

/// A measurement ctx at thread count `t` pinned to ISA level `isa`,
/// running on the shared per-thread-count pool when one exists (scoped
/// threads otherwise).
fn measure_ctx(
    algo: ConvAlgo,
    t: usize,
    isa: IsaLevel,
    shared: Option<&Arc<WorkerPool>>,
) -> ExecCtx {
    let ctx = ExecCtx::with_threads(algo, t).with_isa(isa);
    match shared {
        Some(p) => ctx.with_pool(Arc::clone(p)),
        None => ctx.without_pool(),
    }
}

/// Race the five f32 families at one `(k, threads, isa)` bucket.
fn measure_f32_bucket(
    opts: &AutotuneOpts,
    k: usize,
    t: usize,
    isa: IsaLevel,
    shared: Option<&Arc<WorkerPool>>,
) -> ProfileEntry {
    let case = ConvCase::square(opts.c, opts.hw.max(k + 1), k);
    let x = case.input();
    let w = case.weights();
    let flops = case.flops();

    let mut best: Option<(ConvAlgo, f64)> = None;
    let mut best_sliding: Option<(ConvAlgo, f64)> = None;
    for algo in CANDIDATES {
        if !algo.supports_width(k) {
            continue;
        }
        // Beyond the compound reach `Sliding` silently falls
        // back to the direct kernel; timing it would record a
        // direct measurement under a "sliding" label and poison
        // nearby buckets. Only the real candidates race.
        if k > COMPOUND_MAX_K && tuned_algo_of(algo) == TunedAlgo::Sliding {
            continue;
        }
        // One ctx per candidate: the calibration runs warm its
        // arena, so the timed loop measures steady-state serving.
        let ctx = measure_ctx(algo, t, isa, shared);
        let stats = bench_config(
            || conv2d_ctx(&x, &w, None, &case.params, &ctx),
            opts.samples,
            opts.sample_target,
        );
        let gflops = stats.gflops(flops);
        let beats = |cur: &Option<(ConvAlgo, f64)>| match cur {
            None => true,
            Some((_, g)) => gflops > *g,
        };
        if beats(&best) {
            best = Some((algo, gflops));
        }
        if tuned_algo_of(algo) == TunedAlgo::Sliding && beats(&best_sliding) {
            best_sliding = Some((algo, gflops));
        }
    }
    let (winner, gflops) = best.expect("at least direct always runs");
    let slide = best_sliding
        .map(|(a, _)| row_kernel_of(a, k))
        .unwrap_or_else(|| RowKernel::paper_policy(k.min(COMPOUND_MAX_K)));
    ProfileEntry {
        k,
        threads: t,
        dtype: Dtype::F32,
        isa,
        algo: tuned_algo_of(winner),
        slide,
        gflops,
    }
}

/// Race the int8 families at one `(k, threads)` bucket: quantized
/// sliding vs the int8 im2col+GEMM baseline, both on the raw-accumulator
/// kernels that `conv2d_q8_ctx` routes between. There is no direct int8
/// kernel and no per-width row split (`row_conv_q8` is
/// width-universal), so the bucket is a two-way race and its `slide`
/// field just records the paper-policy family for the width.
fn measure_i8_bucket(
    opts: &AutotuneOpts,
    k: usize,
    t: usize,
    isa: IsaLevel,
    shared: Option<&Arc<WorkerPool>>,
) -> ProfileEntry {
    let case = ConvCase::square(opts.c, opts.hw.max(k + 1), k);
    let x = case.input();
    let w = case.weights();
    let qx = quantize(&x, QuantParams::for_tensor(&x));
    let qw = quantize(&w, QuantParams::for_tensor(&w));
    // Integer MACs counted like FLOPs, as in BENCH_quant.json, so i8
    // and f32 buckets report on one scale.
    let flops = case.flops();

    let slide_ctx = measure_ctx(ConvAlgo::Sliding, t, isa, shared);
    let sliding = bench_config(
        || conv2d_sliding_q8_raw_ctx(&qx, &qw, &case.params, &slide_ctx),
        opts.samples,
        opts.sample_target,
    )
    .gflops(flops);
    let gemm_ctx = measure_ctx(ConvAlgo::Im2colGemm, t, isa, shared);
    let gemm = bench_config(
        || conv2d_im2col_q8_raw_ctx(&qx, &qw, &case.params, &gemm_ctx),
        opts.samples,
        opts.sample_target,
    )
    .gflops(flops);

    let (algo, gflops) = if sliding >= gemm {
        (TunedAlgo::Sliding, sliding)
    } else {
        (TunedAlgo::Gemm, gemm)
    };
    ProfileEntry {
        k,
        threads: t,
        dtype: Dtype::I8,
        isa,
        algo,
        slide: RowKernel::paper_policy(k.min(COMPOUND_MAX_K)),
        gflops,
    }
}

/// A candidate in a tile-shape race (see [`race_tile_shapes`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileCandidate {
    /// The baseline executor — full-plane intermediates, no tiling.
    Untiled,
    /// Cache-budget-sized tiles (what `SWCONV_FORCE_TILE=1` and
    /// `--tile auto` run).
    Auto,
    /// A forced `rows × cols` output-tile shape (`--tile HxW`).
    Fixed(usize, usize),
}

impl TileCandidate {
    /// Human label: `untiled`, `auto`, or `HxW` — the `HxW` form is
    /// exactly what `--tile` accepts back.
    pub fn name(&self) -> String {
        match *self {
            TileCandidate::Untiled => "untiled".into(),
            TileCandidate::Auto => "auto".into(),
            TileCandidate::Fixed(h, w) => format!("{h}x{w}"),
        }
    }
}

/// One measured row of [`race_tile_shapes`].
#[derive(Clone, Debug)]
pub struct TileRaceRow {
    /// The raced shape.
    pub candidate: TileCandidate,
    /// Fusable chains the analysis tiled at this shape (0 on the
    /// untiled baseline row).
    pub chains: usize,
    /// Summed estimated intra-chain working set, in bytes — full-plane
    /// on the untiled row, per-tile on tiled rows.
    pub ws_bytes: u64,
    /// Measured throughput (MACs counted as in the kernel races).
    pub gflops: f64,
}

/// Race output-tile shapes for one model under one ctx — the tiling
/// analogue of the kernel race. Every candidate runs the *same*
/// compiled plan and tiled execution is bit-identical by contract
/// (asserted here before any timing), so the race is purely about
/// locality: the fastest row's [`TileCandidate::name`] is the shape to
/// pass back as `--tile`. The untiled baseline always races; a shape
/// the analysis rejects (no fusable chain under this ctx, or a
/// degenerate grid) is skipped. The dispatch-profile schema is
/// deliberately unchanged — the winning tile is a per-model property,
/// not a per-filter-width bucket.
pub fn race_tile_shapes(
    m: &Model,
    batch: usize,
    ctx: &ExecCtx,
    candidates: &[TileCandidate],
    samples: usize,
    sample_target: Duration,
) -> Vec<TileRaceRow> {
    let batch = batch.max(1);
    let mut shape = vec![batch];
    shape.extend_from_slice(&m.input_shape);
    let x = Tensor::randn(&shape, 1);
    let compiled = m.compile();
    let flops = compiled.flops(batch);
    let want = compiled.run(&x, ctx);
    let budget = CacheInfo::detect().tile_budget_bytes() as u64;

    let mut rows = Vec::new();
    for &cand in candidates {
        let row = match cand {
            TileCandidate::Untiled => {
                // Price the baseline with the auto analysis' untiled
                // (full-plane) estimate over the same chains.
                let auto = tiling::analyze_with(
                    &compiled.graph,
                    None,
                    ctx,
                    batch,
                    TileMode::ForceAll,
                    budget,
                    None,
                );
                let stats = bench_config(|| compiled.run(&x, ctx), samples, sample_target);
                TileRaceRow {
                    candidate: cand,
                    chains: 0,
                    ws_bytes: auto.chains.iter().map(|c| c.untiled_bytes).sum(),
                    gflops: stats.gflops(flops),
                }
            }
            TileCandidate::Auto | TileCandidate::Fixed(..) => {
                let forced = match cand {
                    TileCandidate::Fixed(h, w) => Some((h, w)),
                    _ => None,
                };
                let analysis = tiling::analyze_with(
                    &compiled.graph,
                    None,
                    ctx,
                    batch,
                    TileMode::ForceAll,
                    budget,
                    forced,
                );
                if analysis.is_empty() {
                    continue;
                }
                let chains = analysis.chains.len();
                let ws = analysis.chains.iter().map(|c| c.tiled_bytes).sum();
                let tiled = m.compile().with_tiling(analysis);
                assert_eq!(
                    tiled.run(&x, ctx).as_slice(),
                    want.as_slice(),
                    "tile race {}: tiled execution must be bit-identical",
                    cand.name()
                );
                let stats = bench_config(|| tiled.run(&x, ctx), samples, sample_target);
                TileRaceRow {
                    candidate: cand,
                    chains,
                    ws_bytes: ws,
                    gflops: stats.gflops(flops),
                }
            }
        };
        rows.push(row);
    }
    rows
}

/// Render a profile's crossover table for humans (the CLI and the
/// `ablation_tuned` bench both print this).
pub fn profile_table(profile: &DispatchProfile) -> Table {
    let mut t = Table::new(
        "dispatch profile — measured (k, threads, dtype, isa) winners",
        &["k", "threads", "dtype", "isa", "algo", "slide", "GFLOP/s"],
    );
    for e in profile.entries() {
        t.row(vec![
            e.k.to_string(),
            e.threads.to_string(),
            e.dtype.name().into(),
            e.isa.name().into(),
            e.algo.name().into(),
            e.slide.name().into(),
            f3(e.gflops),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_pass_covers_grid_with_legal_winners() {
        let opts = AutotuneOpts::quick();
        let levels = IsaLevel::available_levels();
        let p = autotune(&opts);
        assert_eq!(p.entries().len(), opts.ks.len() * opts.threads.len() * levels.len());
        for e in p.entries() {
            assert!(opts.ks.contains(&e.k));
            assert!(opts.threads.contains(&e.threads));
            assert!(levels.contains(&e.isa), "{e:?}: unavailable ISA level recorded");
            assert!(e.slide.supports(e.k), "{e:?}: illegal row family recorded");
            assert!(e.gflops > 0.0, "{e:?}: no throughput measured");
        }
        // Every available level got its own buckets.
        for isa in levels {
            assert!(p.entries().iter().any(|e| e.isa == isa), "no {isa} buckets");
        }
        // The table renders one row per entry.
        assert_eq!(profile_table(&p).len(), p.entries().len());
    }

    #[test]
    fn duplicate_grid_points_are_deduped() {
        let mut opts = AutotuneOpts::quick();
        opts.ks = vec![3, 3, 3];
        opts.threads = vec![1, 1];
        let p = autotune(&opts);
        // One bucket per available ISA level, not per duplicate.
        assert_eq!(p.entries().len(), IsaLevel::available_levels().len());
    }

    /// Beyond the compound kernel's reach "sliding" is secretly the
    /// direct fallback — the measured winner must never be recorded
    /// under the sliding label there.
    #[test]
    fn beyond_compound_reach_never_records_sliding() {
        let mut opts = AutotuneOpts::quick();
        opts.ks = vec![COMPOUND_MAX_K + 7];
        let p = autotune(&opts);
        assert_eq!(p.entries().len(), IsaLevel::available_levels().len());
        for e in p.entries() {
            assert_ne!(e.algo, TunedAlgo::Sliding);
        }
    }

    /// The int8 pass fills `dtype: "i8"` buckets (sliding-q8 vs gemm-q8)
    /// that int8 lookups see and f32 lookups don't.
    #[test]
    fn i8_pass_fills_i8_buckets_only() {
        let opts = AutotuneOpts::quick_i8();
        let levels = IsaLevel::available_levels();
        let p = autotune(&opts);
        assert_eq!(p.entries().len(), opts.ks.len() * opts.threads.len() * levels.len());
        for e in p.entries() {
            assert_eq!(e.dtype, Dtype::I8);
            assert!(
                matches!(e.algo, TunedAlgo::Sliding | TunedAlgo::Gemm),
                "{e:?}: int8 race is sliding vs gemm only"
            );
            assert!(e.gflops > 0.0);
            // The winner steers int8 lookups at its own ISA level…
            assert_eq!(p.choice_at(e.k, e.threads, Dtype::I8, e.isa).0, e.algo);
        }
        // …while f32 lookups fall back to the paper policy (no f32
        // buckets were measured by this pass).
        assert_eq!(p.choice(3, 1), (TunedAlgo::Sliding, RowKernel::Custom));
    }

    #[test]
    #[should_panic(expected = "autotune measures f32 or i8")]
    fn non_tunable_dtypes_are_rejected() {
        let opts = AutotuneOpts { dtype: Dtype::Bf16, ..AutotuneOpts::quick() };
        let _ = autotune(&opts);
    }

    /// The tile race always runs the untiled baseline, accepts at least
    /// one tiled shape on a fusable chain model (asserting bit parity
    /// internally), and never prices a tiled row above the full-plane
    /// estimate.
    #[test]
    fn tile_race_covers_candidates_and_shrinks_footprint() {
        use crate::kernels::{Conv2dParams, PoolParams};
        use crate::nn::layers::{Conv2d, MaxPool2d, ReLU};

        let m = Model::new("race", &[3, 16, 16])
            .push(Conv2d::new(3, 4, 3, Conv2dParams::same(3), 21))
            .push(ReLU)
            .push(Conv2d::new(4, 4, 3, Conv2dParams::same(3), 22))
            .push(MaxPool2d(PoolParams::square(2)));
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1).without_pool();
        let cands = [
            TileCandidate::Untiled,
            TileCandidate::Auto,
            TileCandidate::Fixed(4, 4),
            TileCandidate::Fixed(1, 16),
        ];
        let rows =
            race_tile_shapes(&m, 1, &ctx, &cands, 1, Duration::from_micros(200));
        let untiled = rows
            .iter()
            .find(|r| r.candidate == TileCandidate::Untiled)
            .expect("the baseline always races");
        assert!(rows.len() >= 2, "a fusable chain model must accept a tiled shape");
        for r in &rows {
            assert!(r.gflops > 0.0, "{:?}: no throughput measured", r.candidate);
            assert!(!r.candidate.name().is_empty());
            if r.candidate != TileCandidate::Untiled {
                assert!(r.chains >= 1, "{:?}: tiled row without chains", r.candidate);
                assert!(
                    r.ws_bytes <= untiled.ws_bytes,
                    "{:?}: tiling grew the working set",
                    r.candidate
                );
            }
        }
    }
}
