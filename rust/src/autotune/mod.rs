//! Per-machine dispatch autotuning — measure the crossovers, cache the
//! winners, dispatch tuned.
//!
//! The paper's §2 selection policy (custom k=3/5 kernels → the generic
//! in-vector slide up to k=17 → compound vectors beyond) is calibrated
//! to one Xeon 8272CL. On other commodity CPUs the crossovers move with
//! lane width, cache size and core count — the machine-dependence that
//! low-memory GEMM work (arXiv:1709.03395) and ZNNi's per-layer
//! primitive selection (arXiv:1606.05688) show must be *measured*, not
//! assumed. This subsystem does the measuring:
//!
//! * [`autotune`] ([`measure`]) — a microbenchmark pass reusing
//!   [`crate::harness::timing`] and [`crate::exec::ExecCtx`]: per
//!   `(filter-width bucket, thread count, available ISA level)` it
//!   races the direct, GEMM, sliding-generic, sliding-compound and
//!   custom kernels on a representative plane (and, for an `i8` pass,
//!   int8 sliding against the int8 im2col+GEMM baseline, filling the
//!   `dtype: "i8"` buckets quantized tuned routing consults). Measurement contexts resolve
//!   their persistent worker pools like serving contexts do, so the
//!   cached crossovers include real dispatch overheads.
//! * [`DispatchProfile`] ([`profile`]) — the distilled crossover table,
//!   serialized through [`crate::runtime::json`] and cached at
//!   [`default_profile_path`] (`target/autotune/profile.json`) so
//!   serving loads it from disk instead of re-measuring at startup.
//! * [`race_tile_shapes`] ([`measure`]) — the tiling analogue of the
//!   kernel race: time a model's compiled plan untiled vs under
//!   candidate `--tile` output-tile shapes (tiled execution is
//!   bit-identical by contract, asserted before timing) and report
//!   which shape this machine's cache hierarchy prefers. The winner is
//!   a per-model `--tile` argument, not a profile bucket — the cached
//!   schema is unchanged.
//!
//! Dispatch consults the profile in two places: the conv-level
//! [`crate::kernels::ConvAlgo::Tuned`] algorithm resolves each filter
//! width to the measured winner, and the row-level
//! `SlideVariant::Auto` inside the sliding kernel picks the measured
//! row family. Both reach the profile through the
//! [`crate::exec::ExecCtx`] that already carries the algorithm choice —
//! one profile per backend replica, loaded once. Every fallback path
//! (no profile, corrupt profile, out-of-range width) degrades to the
//! paper's hard-coded policy, never to an error.
//!
//! # Examples
//!
//! Measure, cache, reload, dispatch (a real pass — the quick
//! configuration keeps it fast):
//!
//! ```
//! use std::sync::Arc;
//! use swconv::autotune::{autotune, AutotuneOpts, DispatchProfile};
//! use swconv::exec::ExecCtx;
//! use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
//! use swconv::tensor::Tensor;
//!
//! let profile = autotune(&AutotuneOpts::quick());
//! let path = std::env::temp_dir().join("swconv_doc_profile.json");
//! profile.save(&path).unwrap();
//! let loaded = DispatchProfile::load_or_paper(&path);
//! assert_eq!(profile, loaded);
//!
//! // Tuned dispatch: the ctx carries the profile.
//! let ctx = ExecCtx::with_threads(ConvAlgo::Tuned, 1).with_profile(Arc::new(loaded));
//! let x = Tensor::randn(&[1, 1, 12, 12], 1);
//! let w = Tensor::randn(&[1, 1, 3, 3], 2);
//! let y = conv2d_ctx(&x, &w, None, &Conv2dParams::default(), &ctx);
//! assert_eq!(y.dims(), &[1, 1, 10, 10]);
//! # let _ = std::fs::remove_file(path);
//! ```

pub mod measure;
pub mod profile;

pub use measure::{
    autotune, profile_table, race_tile_shapes, AutotuneOpts, TileCandidate, TileRaceRow,
};
pub use profile::{default_profile_path, DispatchProfile, ProfileEntry, TunedAlgo};
