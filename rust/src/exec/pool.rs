//! The persistent worker pool: long-lived, optionally core-pinned worker
//! threads that parallel regions submit to, instead of spawning scoped
//! threads per region.
//!
//! The paper's sliding kernels win precisely where planes are small —
//! and there a ~10 µs thread spawn per parallel region is a measurable
//! tax on a ~100 µs convolution (`benches/pool_overhead.rs` quantifies
//! it). ZNNi's (arXiv:1606.05688) CPU conv throughput argument is built
//! on workers staying resident with their memory local; SLIDE
//! (arXiv:1903.03129) shows the same about deliberate thread/affinity
//! management. [`WorkerPool`] is that refactor:
//!
//! * **Work stealing** — each worker owns an injector deque (a region
//!   submission deals range `r` to deque `r % workers`);
//!   a worker pops its own deque from the front and steals from the
//!   others' backs when empty, so an uneven region drains at the speed
//!   of the free workers, not the slowest assignment.
//! * **Condvar parking** — workers with nothing to run park on a condvar
//!   and are woken per submission: an idle pool burns no cycles.
//! * **Region semantics** — the submitting thread runs the *last* range
//!   itself (exactly like the scoped path it replaces), then blocks
//!   until the pool has finished the rest. A panic in any range is
//!   caught on the worker, re-thrown on the submitter once the region
//!   has fully drained, and poisons **only that region** — the workers
//!   survive and keep serving later regions.
//! * **Nested regions run inline** — a parallel region opened *from* a
//!   pool worker (a kernel called inside another kernel's chunk)
//!   executes sequentially on that worker instead of re-entering the
//!   pool, so nesting can never deadlock ([`on_pool_worker`]).
//! * **Determinism** — the pool schedules *which thread* runs a range,
//!   never *what* the range computes: partitioning stays the same
//!   contiguous arithmetic as the scoped path, so results remain
//!   bit-identical for any worker count, pooled or not.
//!
//! The pool is the default execution path ([`super::ExecCtx`] builds one
//! lazily on first use); `SWCONV_NO_POOL=1` in the environment — or the
//! CLI's `--no-pool`, which calls [`set_pooling_disabled`] — restores
//! spawn-per-region scoped threads everywhere, as a fallback and as the
//! baseline the overhead bench compares against.

use super::affinity::CoreSet;
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once};
use std::thread::JoinHandle;

/// One queued unit of work: range `range` of the region behind `region`.
/// The raw pointer is sound because [`WorkerPool::run_region`] does not
/// return (and therefore the region and everything it borrows stays
/// alive) until every task of the region has finished.
struct Task {
    region: *const RegionCore,
    range: usize,
}

// SAFETY: a Task only crosses threads inside the pool, and the region it
// points to outlives its execution (the submitter blocks on the region's
// completion latch); the closure it runs is `Sync` by construction.
unsafe impl Send for Task {}

/// The shared state of one parallel region, owned by the submitting
/// thread's stack frame for the duration of [`WorkerPool::run_region`].
struct RegionCore {
    /// The range runner, lifetime-erased; see `run_region` for why the
    /// erasure is sound.
    run: &'static (dyn Fn(usize) + Sync),
    /// Tasks handed to the pool and not yet finished.
    pending: AtomicUsize,
    /// First panic payload caught in any range (worker or submitter).
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Completion latch: set under the mutex by the worker that finishes
    /// the last task, waited on by the submitter.
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl RegionCore {
    /// Record the first panic of the region (later ones are dropped —
    /// the scoped path it replaces also rethrows a single payload).
    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = self.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
}

/// What the pool's threads share.
struct Inner {
    /// One injector deque per worker; range `r` is dealt to deque
    /// `r % workers`, owners pop the front, thieves steal the back.
    queues: Vec<Mutex<VecDeque<Task>>>,
    /// Parking lot: workers with no runnable task wait here.
    sleep: Mutex<()>,
    wake: Condvar,
    shutdown: AtomicBool,
    /// Workers currently alive (incremented at thread start, decremented
    /// on exit): the observable behind [`WorkerPool::live_workers`].
    live: Arc<AtomicUsize>,
}

impl Inner {
    /// Pop (own queue, front) or steal (other queues, back).
    fn find_task(&self, me: usize) -> Option<Task> {
        if let Some(t) = self.queues[me].lock().unwrap().pop_front() {
            return Some(t);
        }
        let n = self.queues.len();
        for off in 1..n {
            let victim = (me + off) % n;
            if let Some(t) = self.queues[victim].lock().unwrap().pop_back() {
                return Some(t);
            }
        }
        None
    }

    fn any_task(&self) -> bool {
        self.queues.iter().any(|q| !q.lock().unwrap().is_empty())
    }

    /// Run one task: the range body under `catch_unwind`, then the
    /// region's completion accounting. After the final `pending`
    /// decrement's latch handoff the region pointer is never touched
    /// again, which is what makes the submitter's stack ownership sound.
    fn execute(&self, task: Task) {
        // SAFETY: see `Task` — the region outlives this call.
        let region = unsafe { &*task.region };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| (region.run)(task.range))) {
            region.record_panic(p);
        }
        if region.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last task: flip the latch *under its mutex* and notify
            // while still holding it — the submitter can only observe
            // `done` through the same mutex, so it cannot free the
            // region before this worker is finished with it.
            let mut done = region.done.lock().unwrap();
            *done = true;
            region.done_cv.notify_all();
        }
    }
}

/// A persistent pool of worker threads executing [`super::ExecCtx`]
/// parallel regions. Construct once (or let `ExecCtx` build one lazily),
/// share via `Arc`; dropping the last handle shuts the workers down and
/// **joins** them.
///
/// # Examples
///
/// ```
/// use swconv::exec::{ExecCtx, WorkerPool};
/// use swconv::kernels::ConvAlgo;
///
/// let pool = WorkerPool::new(3);
/// let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(pool);
/// let mut data = vec![0.0f32; 8];
/// ctx.par_chunks(&mut data, 2, |i, c| c.fill(i as f32));
/// assert_eq!(data, [0., 0., 1., 1., 2., 2., 3., 3.]);
/// // Dropping the last handle (the ctx's) joins the three workers.
/// ```
pub struct WorkerPool {
    inner: Arc<Inner>,
    joins: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
    cores: Option<CoreSet>,
}

impl WorkerPool {
    /// Spawn `workers` (clamped to ≥ 1) resident worker threads, named
    /// `swconv-pool-w<i>`, with no core pinning.
    pub fn new(workers: usize) -> Arc<WorkerPool> {
        Self::build(workers, None)
    }

    /// [`WorkerPool::new`] with affinity: worker `w` pins itself to core
    /// `cores.nth_wrapped(w)` before serving, so the scratch it
    /// first-touches is resident on its own core's memory node.
    /// Pinning is best-effort ([`super::affinity::pin_current`]).
    pub fn pinned(workers: usize, cores: CoreSet) -> Arc<WorkerPool> {
        Self::build(workers, if cores.is_empty() { None } else { Some(cores) })
    }

    fn build(workers: usize, cores: Option<CoreSet>) -> Arc<WorkerPool> {
        let workers = workers.max(1);
        let inner = Arc::new(Inner {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            live: Arc::new(AtomicUsize::new(0)),
        });
        let mut joins = Vec::with_capacity(workers);
        for w in 0..workers {
            let inner = Arc::clone(&inner);
            let pin = cores.as_ref().map(|c| c.nth_wrapped(w));
            let join = std::thread::Builder::new()
                .name(format!("swconv-pool-w{w}"))
                .spawn(move || worker_main(&inner, w, pin))
                .expect("spawn pool worker");
            joins.push(join);
        }
        // Wait (bounded, sleeping on the pool's own condvar — each
        // worker signals after incrementing `live`) for the workers to
        // come up before handing the pool out: the first region then
        // fans out over live, parked workers — exactly the concurrency
        // the scoped path had — so the arena's first-call scratch
        // high-water mark stays deterministic instead of depending on
        // thread-spawn latency.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        let mut parked = inner.sleep.lock().unwrap();
        while inner.live.load(Ordering::Acquire) < workers {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = inner.wake.wait_timeout(parked, deadline - now).unwrap();
            parked = guard;
        }
        drop(parked);
        Arc::new(WorkerPool { inner, joins: Mutex::new(joins), workers, cores })
    }

    /// Resident worker-thread count (the submitter is not counted: a
    /// region of `workers() + 1` ranges still has every range running
    /// concurrently).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The core set workers pinned themselves to, if any.
    pub fn cores(&self) -> Option<&CoreSet> {
        self.cores.as_ref()
    }

    /// Worker threads currently alive. Rises to [`WorkerPool::workers`]
    /// as the threads start and — because `Drop` joins — is exactly zero
    /// once the last pool handle is gone.
    pub fn live_workers(&self) -> usize {
        self.inner.live.load(Ordering::Acquire)
    }

    /// A probe for the live-worker count that outlives the pool: the
    /// lifecycle tests hold one across `drop(pool)` to assert the drop
    /// actually joined every worker.
    pub fn live_workers_probe(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.inner.live)
    }

    /// Execute one parallel region of `ranges` ranges: ranges
    /// `0..ranges-1` are dealt to the worker deques, range `ranges - 1`
    /// runs on the calling thread (mirroring the scoped path's "last
    /// range on the caller"), and the call returns only when every range
    /// has finished. If any range panicked, the first payload is
    /// re-thrown here — after the region has fully drained, so the
    /// borrows inside `run` stay valid for the stragglers.
    pub(crate) fn run_region(&self, ranges: usize, run: &(dyn Fn(usize) + Sync)) {
        if ranges == 0 {
            return;
        }
        if ranges == 1 {
            run(0);
            return;
        }
        // SAFETY (lifetime erasure): the `'static` is a lie told only to
        // the type system. Every path out of this function — normal
        // return, submitter panic, worker panic — first waits for
        // `pending` to reach zero, so no worker can dereference `run`
        // (or anything it borrows) after this frame is gone.
        let run_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(run) };
        let submitted = ranges - 1;
        let region = RegionCore {
            run: run_static,
            pending: AtomicUsize::new(submitted),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        };
        for r in 0..submitted {
            let queue = &self.inner.queues[r % self.workers];
            queue.lock().unwrap().push_back(Task { region: &region, range: r });
        }
        {
            // Taking the sleep lock before notifying closes the race
            // with a worker that found nothing and is about to park: it
            // re-checks the queues under this same lock. One wake per
            // submitted range (capped at the pool size) — waking the
            // whole pool for a two-range region would send every loser
            // through a futile scan-and-repark on each small conv, the
            // very overhead this pool exists to retire. Busy workers
            // need no signal: they re-run find_task after every task.
            let _parked = self.inner.sleep.lock().unwrap();
            for _ in 0..submitted.min(self.workers) {
                self.inner.wake.notify_one();
            }
        }
        // The caller's own range: caught so an early submitter panic
        // cannot unwind past workers still borrowing the region.
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| run(ranges - 1))) {
            region.record_panic(p);
        }
        let mut done = region.done.lock().unwrap();
        while !*done {
            done = region.done_cv.wait(done).unwrap();
        }
        drop(done);
        if let Some(p) = region.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    /// Shut down and **join** every worker: after the last `Arc` handle
    /// is gone no pool thread is left running (the lifecycle tests pin
    /// this via [`WorkerPool::live_workers_probe`]).
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        {
            let _parked = self.inner.sleep.lock().unwrap();
            self.inner.wake.notify_all();
        }
        for join in self.joins.lock().unwrap().drain(..) {
            let _ = join.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("cores", &self.cores)
            .finish()
    }
}

/// Worker thread body: pin if asked, mark this thread as a pool worker
/// (so nested regions run inline), then pop/steal/park until shutdown.
fn worker_main(inner: &Arc<Inner>, me: usize, pin: Option<usize>) {
    if let Some(core) = pin {
        // Best-effort: a sandbox that rejects the syscall leaves this
        // worker floating, which is slower but never wrong.
        super::affinity::pin_current_to_core(core);
    }
    WORKER_SLOT.with(|slot| slot.set(Some(me)));
    inner.live.fetch_add(1, Ordering::AcqRel);
    {
        // Signal the constructor's startup wait (stray wakes just send
        // parked siblings through a re-check; startup-only, harmless).
        let _parked = inner.sleep.lock().unwrap();
        inner.wake.notify_all();
    }
    loop {
        if let Some(task) = inner.find_task(me) {
            inner.execute(task);
            continue;
        }
        let parked = inner.sleep.lock().unwrap();
        // Drain-before-exit: shutdown only stops the worker once no
        // queued region work remains (a region submitter still holds a
        // pool handle, so this is belt and braces, not load-bearing).
        if inner.shutdown.load(Ordering::Acquire) && !inner.any_task() {
            break;
        }
        if inner.any_task() {
            continue;
        }
        let _parked = inner.wake.wait(parked).unwrap();
    }
    inner.live.fetch_sub(1, Ordering::AcqRel);
}

thread_local! {
    /// `Some(worker index)` on pool worker threads, `None` elsewhere.
    static WORKER_SLOT: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// The pool-worker slot of the current thread, if it is one: the arena
/// uses it to prefer handing a worker back the buffers it first-touched.
pub(crate) fn current_worker_slot() -> Option<usize> {
    WORKER_SLOT.with(|slot| slot.get())
}

/// Whether the current thread is a pool worker. Parallel regions opened
/// on a pool worker run inline (sequentially) instead of re-entering a
/// pool, so nested `par_chunks` cannot deadlock.
pub fn on_pool_worker() -> bool {
    current_worker_slot().is_some()
}

static POOLING_DISABLED: AtomicBool = AtomicBool::new(false);
static POOLING_INIT: Once = Once::new();

/// Whether persistent pools are globally disabled — by `SWCONV_NO_POOL`
/// in the environment (any value but `0` or empty), or by
/// [`set_pooling_disabled`] (the CLI's `--no-pool`). Disabled pooling
/// restores the scoped spawn-per-region path bit for bit.
pub fn pooling_disabled() -> bool {
    POOLING_INIT.call_once(|| {
        let from_env =
            matches!(std::env::var("SWCONV_NO_POOL"), Ok(v) if !v.is_empty() && v != "0");
        POOLING_DISABLED.store(from_env, Ordering::Relaxed);
    });
    POOLING_DISABLED.load(Ordering::Relaxed)
}

/// Globally enable/disable persistent pools (overrides the environment;
/// affects contexts whose pool has not been resolved yet, not pools
/// already running).
pub fn set_pooling_disabled(disabled: bool) {
    POOLING_INIT.call_once(|| {});
    POOLING_DISABLED.store(disabled, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn region_covers_every_range_exactly_once() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..10).map(|_| AtomicUsize::new(0)).collect();
        pool.run_region(10, &|r| {
            hits[r].fetch_add(1, Ordering::Relaxed);
        });
        for (r, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "range {r}");
        }
    }

    #[test]
    fn single_range_runs_inline() {
        let pool = WorkerPool::new(2);
        let mut x = 0;
        // A 1-range region must not need Sync state: it runs here.
        pool.run_region(1, &|r| assert_eq!(r, 0));
        x += 1;
        assert_eq!(x, 1);
        pool.run_region(0, &|_| panic!("no ranges, no calls"));
    }

    #[test]
    fn workers_park_and_wake_across_many_regions() {
        let pool = WorkerPool::new(2);
        let sum = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run_region(4, &|r| {
                sum.fetch_add(r + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 100 * (1 + 2 + 3 + 4));
    }

    #[test]
    fn panic_poisons_only_its_region() {
        let pool = WorkerPool::new(2);
        let survivors = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_region(6, &|r| {
                if r == 2 {
                    panic!("chunk 2 exploded");
                }
                survivors.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "the region's submitter must see the panic");
        // The region drained fully before rethrowing…
        assert_eq!(survivors.load(Ordering::Relaxed), 5);
        // …and the pool still serves later regions with all workers.
        assert_eq!(pool.live_workers(), 2);
        let ok = AtomicUsize::new(0);
        pool.run_region(6, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = WorkerPool::new(3);
        let probe = pool.live_workers_probe();
        // Wait for startup (threads race the constructor's return).
        let t0 = std::time::Instant::now();
        while probe.load(Ordering::Acquire) < 3 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::yield_now();
        }
        assert_eq!(probe.load(Ordering::Acquire), 3);
        drop(pool);
        // Drop joined, so this is exact, not eventual.
        assert_eq!(probe.load(Ordering::Acquire), 0);
    }

    #[test]
    fn worker_count_is_clamped_and_reported() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        assert!(pool.cores().is_none());
        let pinned = WorkerPool::pinned(2, CoreSet::from_cores(&[0]));
        assert_eq!(pinned.cores().map(|c| c.cores()), Some(&[0][..]));
        let unset = WorkerPool::pinned(2, CoreSet::from_cores(&[]));
        assert!(unset.cores().is_none());
    }

    // The global disable flag is exercised (together with the lazy pool
    // it gates) by `tests/pool_flag.rs`, a dedicated integration binary:
    // its own process, so flipping the flag races nothing.
}
