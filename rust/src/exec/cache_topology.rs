//! Cache-hierarchy probe: sysfs-backed detection of the L1d/L2/L3
//! sizes the tiler sizes its working sets against.
//!
//! The tiled executor ([`crate::graph::tiling`]) keeps a fused chain's
//! per-tile working set inside the innermost *private* cache level big
//! enough to matter — on every x86/ARM server that is L2 — so it needs
//! to know how big L2 actually is on this machine. Linux exposes the
//! topology under `/sys/devices/system/cpu/cpu*/cache/index*` (one
//! directory per cache instance per CPU, with `level`, `size`, `type`
//! and `shared_cpu_list` files); [`detect`] parses cpu0's view of it
//! once per process and caches the result.
//!
//! Detection is **never** load-bearing for correctness — tile shape
//! changes which rectangles the region kernels compute, not their
//! values — so every failure mode degrades to a conservative fallback
//! ([`CacheInfo::fallback`]: 32 KiB L1d / 512 KiB L2 / 8 MiB L3,
//! modest sizes that fit inside any server core of the last decade).
//! The `SWCONV_L2_KB` / `SWCONV_L3_KB` environment variables override
//! the detected (or fallen-back) sizes, giving benchmarks and CI an
//! exact, machine-independent lever; `swconv cache-info` prints the
//! whole struct so the tiler's inputs are inspectable.

use super::affinity::CoreSet;
use std::path::Path;
use std::sync::OnceLock;

/// Where the cache sizes came from, for the `cache-info` report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheSource {
    /// Parsed from `/sys/devices/system/cpu/cpu0/cache/`.
    Sysfs,
    /// The conservative built-in fallback (sysfs missing or malformed).
    Fallback,
}

/// The cache hierarchy as the tiler sees it: one size per level, plus
/// how many CPUs share each L2/L3 instance (1 = private).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheInfo {
    /// L1 data cache size in bytes.
    pub l1d_bytes: usize,
    /// L2 (unified) cache size in bytes — the tiler's working-set
    /// target.
    pub l2_bytes: usize,
    /// L3 (last-level) cache size in bytes; 0 when the machine reports
    /// none.
    pub l3_bytes: usize,
    /// CPUs sharing one L2 instance (1 on most x86 cores, 2 with SMT
    /// siblings listed, more on clustered designs).
    pub l2_shared_by: usize,
    /// CPUs sharing one L3 instance (typically the whole socket/CCX).
    pub l3_shared_by: usize,
    /// Whether the sizes were probed or fallen back to.
    pub source: CacheSource,
    /// True when `SWCONV_L2_KB`/`SWCONV_L3_KB` overrode a size.
    pub overridden: bool,
}

impl CacheInfo {
    /// The conservative fallback: 32 KiB L1d, 512 KiB private L2,
    /// 8 MiB shared L3. Small enough to be real on any supported
    /// machine, so a tiler sized from it never *overestimates* the
    /// cache it is trying to stay resident in.
    pub fn fallback() -> CacheInfo {
        CacheInfo {
            l1d_bytes: 32 << 10,
            l2_bytes: 512 << 10,
            l3_bytes: 8 << 20,
            l2_shared_by: 1,
            l3_shared_by: 1,
            source: CacheSource::Fallback,
            overridden: false,
        }
    }

    /// Human-readable multi-line report (what `swconv cache-info`
    /// prints).
    pub fn render(&self) -> String {
        let src = match self.source {
            CacheSource::Sysfs => "sysfs (/sys/devices/system/cpu/cpu0/cache)",
            CacheSource::Fallback => "built-in fallback (sysfs unavailable)",
        };
        let mut out = String::new();
        out.push_str(&format!("source : {src}\n"));
        if self.overridden {
            out.push_str("         (sizes overridden via SWCONV_L2_KB/SWCONV_L3_KB)\n");
        }
        out.push_str(&format!("L1d    : {}\n", fmt_size(self.l1d_bytes)));
        out.push_str(&format!(
            "L2     : {} (shared by {} cpu(s))\n",
            fmt_size(self.l2_bytes),
            self.l2_shared_by
        ));
        if self.l3_bytes > 0 {
            out.push_str(&format!(
                "L3     : {} (shared by {} cpu(s))\n",
                fmt_size(self.l3_bytes),
                self.l3_shared_by
            ));
        } else {
            out.push_str("L3     : none reported\n");
        }
        out.push_str(&format!(
            "tile working-set budget: {} (3/4 of L2)\n",
            fmt_size(self.tile_budget_bytes())
        ));
        out
    }

    /// The per-tile working-set budget the tiler targets: 3/4 of L2,
    /// leaving headroom for weights, row scratch and the stack. This is
    /// a *goal*, not a contract — a chain whose minimum tile (1×1
    /// output) still exceeds it simply runs with the minimum tile.
    pub fn tile_budget_bytes(&self) -> usize {
        (self.l2_bytes / 4) * 3
    }

    /// Associated form of the module-level [`detect`]: the probed (and
    /// process-cached) hierarchy.
    pub fn detect() -> CacheInfo {
        detect()
    }
}

/// Format a byte count in binary units for the report.
fn fmt_size(b: usize) -> String {
    if b >= 1 << 20 && b % (1 << 20) == 0 {
        format!("{} MiB", b >> 20)
    } else if b >= 1 << 10 {
        format!("{} KiB", b >> 10)
    } else {
        format!("{b} B")
    }
}

/// Parse a sysfs cache `size` file: a decimal count with an optional
/// `K`/`M`/`G` binary suffix (sysfs writes e.g. `512K`, `32M`).
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.char_indices().find(|(_, c)| !c.is_ascii_digit()) {
        None => (s, 1usize),
        Some((i, c)) => {
            let mult = match c.to_ascii_uppercase() {
                'K' => 1usize << 10,
                'M' => 1usize << 20,
                'G' => 1usize << 30,
                _ => return None,
            };
            (&s[..i], mult)
        }
    };
    digits.parse::<usize>().ok().map(|n| n.saturating_mul(mult))
}

/// How many CPUs a `shared_cpu_list` file names (`0-3,8` → 5). Zero or
/// unparseable lists answer 1 (assume private).
fn parse_shared_count(s: &str) -> usize {
    match CoreSet::parse(s.trim()) {
        Ok(set) => set.len().max(1),
        Err(_) => 1,
    }
}

fn read_trimmed(p: &Path) -> Option<String> {
    std::fs::read_to_string(p).ok().map(|s| s.trim().to_string())
}

/// Probe cpu0's cache directories under `root` (the
/// `/sys/devices/system/cpu` prefix — parameterized for tests).
/// `None` when no usable L2 was found.
fn probe_sysfs_at(root: &Path) -> Option<CacheInfo> {
    let cache = root.join("cpu0/cache");
    let mut info = CacheInfo { source: CacheSource::Sysfs, l3_bytes: 0, ..CacheInfo::fallback() };
    let mut saw_l2 = false;
    // index0..index9 covers every real topology (3–5 instances).
    for i in 0..10 {
        let dir = cache.join(format!("index{i}"));
        if !dir.is_dir() {
            continue;
        }
        let level = read_trimmed(&dir.join("level")).and_then(|s| s.parse::<usize>().ok());
        let ty = read_trimmed(&dir.join("type")).unwrap_or_default();
        let size = read_trimmed(&dir.join("size")).and_then(|s| parse_size(&s));
        let shared = read_trimmed(&dir.join("shared_cpu_list"))
            .map(|s| parse_shared_count(&s))
            .unwrap_or(1);
        let (Some(level), Some(size)) = (level, size) else { continue };
        match (level, ty.as_str()) {
            (1, "Data") => info.l1d_bytes = size,
            // L2/L3 are "Unified" everywhere that matters; accept a
            // missing type file too.
            (2, "Unified" | "Data" | "") => {
                info.l2_bytes = size;
                info.l2_shared_by = shared;
                saw_l2 = true;
            }
            (3, "Unified" | "Data" | "") => {
                info.l3_bytes = size;
                info.l3_shared_by = shared;
            }
            _ => {}
        }
    }
    saw_l2.then_some(info)
}

/// Apply the `SWCONV_L2_KB`/`SWCONV_L3_KB` overrides (decimal KiB
/// counts; unparseable or zero values are ignored).
fn apply_overrides(mut info: CacheInfo) -> CacheInfo {
    if let Ok(v) = std::env::var("SWCONV_L2_KB") {
        if let Ok(kb) = v.trim().parse::<usize>() {
            if kb > 0 {
                info.l2_bytes = kb << 10;
                info.overridden = true;
            }
        }
    }
    if let Ok(v) = std::env::var("SWCONV_L3_KB") {
        if let Ok(kb) = v.trim().parse::<usize>() {
            if kb > 0 {
                info.l3_bytes = kb << 10;
                info.overridden = true;
            }
        }
    }
    info
}

/// The machine's cache hierarchy: sysfs-probed on first call (with the
/// conservative fallback when the probe fails) plus the environment
/// overrides, then cached for the process lifetime.
pub fn detect() -> CacheInfo {
    static DETECTED: OnceLock<CacheInfo> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        let probed = probe_sysfs_at(Path::new("/sys/devices/system/cpu"))
            .unwrap_or_else(CacheInfo::fallback);
        apply_overrides(probed)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_suffixes_parse() {
        assert_eq!(parse_size("512K"), Some(512 << 10));
        assert_eq!(parse_size("32M"), Some(32 << 20));
        assert_eq!(parse_size("1G"), Some(1 << 30));
        assert_eq!(parse_size("448"), Some(448));
        assert_eq!(parse_size(" 64K\n"), Some(64 << 10));
        assert_eq!(parse_size("64KB"), None, "sysfs never writes KB");
        assert_eq!(parse_size("abc"), None);
        assert_eq!(parse_size(""), None);
    }

    #[test]
    fn shared_lists_count_cpus() {
        assert_eq!(parse_shared_count("0"), 1);
        assert_eq!(parse_shared_count("0-3"), 4);
        assert_eq!(parse_shared_count("0-3,8"), 5);
        assert_eq!(parse_shared_count("garbage"), 1);
    }

    #[test]
    fn fallback_is_conservative_and_budget_is_three_quarters() {
        let f = CacheInfo::fallback();
        assert_eq!(f.l2_bytes, 512 << 10);
        assert_eq!(f.tile_budget_bytes(), 384 << 10);
        assert_eq!(f.source, CacheSource::Fallback);
        assert!(!f.overridden);
    }

    #[test]
    fn probe_parses_a_synthetic_topology() {
        let root = std::env::temp_dir().join("swconv_test_cache_topo");
        let mk = |idx: usize, level: &str, ty: &str, size: &str, shared: &str| {
            let d = root.join(format!("cpu0/cache/index{idx}"));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("level"), level).unwrap();
            std::fs::write(d.join("type"), ty).unwrap();
            std::fs::write(d.join("size"), size).unwrap();
            std::fs::write(d.join("shared_cpu_list"), shared).unwrap();
        };
        mk(0, "1", "Data", "48K", "0-1");
        mk(1, "1", "Instruction", "32K", "0-1");
        mk(2, "2", "Unified", "1280K", "0-1");
        mk(3, "3", "Unified", "24M", "0-15");
        let info = probe_sysfs_at(&root).expect("synthetic topology must probe");
        assert_eq!(info.l1d_bytes, 48 << 10);
        assert_eq!(info.l2_bytes, 1280 << 10);
        assert_eq!(info.l2_shared_by, 2);
        assert_eq!(info.l3_bytes, 24 << 20);
        assert_eq!(info.l3_shared_by, 16);
        assert_eq!(info.source, CacheSource::Sysfs);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn probe_without_l2_degrades_to_none() {
        let root = std::env::temp_dir().join("swconv_test_cache_topo_empty");
        std::fs::create_dir_all(root.join("cpu0/cache")).unwrap();
        assert_eq!(probe_sysfs_at(&root), None);
        let _ = std::fs::remove_dir_all(&root);
        assert_eq!(probe_sysfs_at(Path::new("/definitely/not/here")), None);
    }

    #[test]
    fn render_mentions_every_level() {
        let s = CacheInfo::fallback().render();
        assert!(s.contains("L1d"));
        assert!(s.contains("L2"));
        assert!(s.contains("L3"));
        assert!(s.contains("budget"));
    }

    #[test]
    fn detect_is_cached_and_total() {
        let a = detect();
        let b = detect();
        assert_eq!(a, b, "detection must be cached");
        assert!(a.l2_bytes > 0);
    }
}
