//! The execution-context subsystem: worker threads + a scratch-buffer
//! arena, threaded through every kernel layer.
//!
//! The paper's precursor (arXiv:2305.16513) stresses that sliding-window
//! kernels parallelize naturally across independent output rows, and
//! ZNNi (arXiv:1606.05688) shows CPU conv throughput is won by saturating
//! all cores while controlling working-set memory. [`ExecCtx`] is the
//! carrier for both:
//!
//! * **Threads** — [`ExecCtx::par_chunks`] fans independent work items
//!   (one output plane / row / group block each) out over `threads`
//!   std scoped threads (no dependencies, no persistent pool to keep
//!   `Send` bounds simple). Items are split into *contiguous* ranges so
//!   each worker owns a disjoint `&mut` window of the output — no
//!   unsafe, no locks on the hot path — and every item is computed with
//!   exactly the same instruction sequence regardless of which worker
//!   runs it, so results are **bit-identical** for any thread count.
//! * **Scratch arena** — [`ExecCtx::take`]/[`ExecCtx::put`] check
//!   reusable `Vec<f32>` buffers in and out of a shared free list, so
//!   the padded-input / row-accumulator / im2col-column buffers that
//!   every kernel needs are allocated once and reused across calls
//!   (the coordinator keeps one ctx per backend, so batched serving
//!   stops paying allocation churn per request).
//!   [`ExecCtx::alloc_events`] counts buffer growths so tests can
//!   assert the steady state allocates nothing.
//!
//! `ExecCtx` also carries the convolution-algorithm choice
//! ([`ConvAlgo`]) that the per-request router switches — which is all it
//! used to be before this subsystem existed — and, optionally, a
//! measured [`DispatchProfile`] ([`ExecCtx::with_profile`]) that the
//! tuned dispatch paths ([`ConvAlgo::Tuned`], `SlideVariant::Auto`)
//! consult instead of the paper's hard-coded k=17 crossover policy.

use crate::autotune::{DispatchProfile, TunedAlgo};
use crate::kernels::rowconv::RowKernel;
use crate::kernels::ConvAlgo;
use std::cell::RefCell;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Per-request / per-backend execution context: algorithm selection,
/// worker-thread count, the scratch-buffer arena and (optionally) the
/// machine's measured dispatch profile.
///
/// Cheap to construct; construct once and reuse to amortise scratch
/// allocations. Not `Copy` (it owns the arena) — build with
/// [`ExecCtx::new`] / [`ExecCtx::with_threads`] / [`ExecCtx::auto`].
///
/// # Examples
///
/// Serve the same workload single- and multi-threaded; results are
/// bit-identical and the second call reuses the first call's scratch:
///
/// ```
/// use swconv::exec::ExecCtx;
/// use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
/// use swconv::tensor::Tensor;
///
/// let x = Tensor::randn(&[1, 2, 16, 16], 1);
/// let w = Tensor::randn(&[4, 2, 3, 3], 2);
/// let p = Conv2dParams::same(3);
///
/// let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
/// let warm = conv2d_ctx(&x, &w, None, &p, &ctx);
/// let allocs = ctx.alloc_events();
/// let again = conv2d_ctx(&x, &w, None, &p, &ctx);
/// assert_eq!(warm.as_slice(), again.as_slice());
/// assert_eq!(ctx.alloc_events(), allocs, "steady state allocates nothing");
///
/// let one = ExecCtx::new(ConvAlgo::Sliding);
/// assert_eq!(conv2d_ctx(&x, &w, None, &p, &one).as_slice(), warm.as_slice());
/// ```
pub struct ExecCtx {
    /// Convolution algorithm for all conv layers routed through this ctx.
    pub algo: ConvAlgo,
    threads: usize,
    arena: Mutex<Vec<Vec<f32>>>,
    allocs: AtomicUsize,
    /// Measured dispatch profile, shared across replicas via `Arc`;
    /// `None` means every tuned lookup answers with the paper policy.
    profile: Option<Arc<DispatchProfile>>,
}

impl ExecCtx {
    /// Single-threaded context with the given algorithm (the exact
    /// behaviour of the pre-subsystem `ExecCtx { algo }`).
    pub fn new(algo: ConvAlgo) -> Self {
        Self::with_threads(algo, 1)
    }

    /// Context with an explicit worker-thread count (clamped to ≥ 1).
    pub fn with_threads(algo: ConvAlgo, threads: usize) -> Self {
        ExecCtx {
            algo,
            threads: threads.max(1),
            arena: Mutex::new(Vec::new()),
            allocs: AtomicUsize::new(0),
            profile: None,
        }
    }

    /// Context using every available hardware thread
    /// (see [`available_threads`]).
    pub fn auto(algo: ConvAlgo) -> Self {
        Self::with_threads(algo, available_threads())
    }

    /// Attach a measured dispatch profile (builder style). The tuned
    /// dispatch paths — [`ConvAlgo::Tuned`] and the sliding kernel's
    /// `Auto` row selection — consult it via [`ExecCtx::tuned_choice`] /
    /// [`ExecCtx::tuned_row_kernel`]; without one they answer with the
    /// paper's §2 policy.
    pub fn with_profile(mut self, profile: Arc<DispatchProfile>) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Install (or replace) the dispatch profile on an existing context
    /// — what [`crate::coordinator::BackendSpec::with_profile`] does to
    /// each replica's backend right after construction.
    pub fn set_profile(&mut self, profile: Arc<DispatchProfile>) {
        self.profile = Some(profile);
    }

    /// The attached dispatch profile, if any.
    pub fn profile(&self) -> Option<&Arc<DispatchProfile>> {
        self.profile.as_ref()
    }

    /// Tuned `(conv-level algorithm, row family)` for filter width `k`
    /// at this ctx's thread count: the profile's nearest-bucket answer,
    /// or the paper policy when no profile is attached. Always legal —
    /// see [`DispatchProfile::choice`] for the clamping rules.
    pub fn tuned_choice(&self, k: usize) -> (TunedAlgo, RowKernel) {
        match &self.profile {
            Some(p) => p.choice(k, self.threads),
            None => DispatchProfile::paper_policy().choice(k, self.threads),
        }
    }

    /// The tuned row-kernel family for width `k` (the
    /// [`ExecCtx::tuned_choice`] slide component): what
    /// `SlideVariant::Auto` runs per row.
    pub fn tuned_row_kernel(&self, k: usize) -> RowKernel {
        self.tuned_choice(k).1
    }

    /// Worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of scratch-buffer allocations (or capacity growths) so
    /// far. Steady-state kernel calls must not move this counter — the
    /// arena-reuse tests assert exactly that.
    pub fn alloc_events(&self) -> usize {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Check a buffer of `len` elements, every element set to `fill`,
    /// out of the arena; return it with [`ExecCtx::put`] when done.
    ///
    /// Best-fit reuse: the smallest free buffer whose capacity already
    /// holds `len`, else the largest available (which grows once and
    /// then keeps its capacity). Best-fit keeps small requests from
    /// stealing large buffers, so a warmed arena serves a repeating
    /// workload with zero allocations in any take order.
    pub fn take(&self, len: usize, fill: f32) -> Vec<f32> {
        let mut buf = self.pick(len);
        let before = buf.capacity();
        buf.clear();
        buf.resize(len, fill);
        if buf.capacity() > before {
            self.allocs.fetch_add(1, Ordering::Relaxed);
        }
        buf
    }

    /// [`ExecCtx::take`] without the refill: the buffer has `len`
    /// elements of **unspecified** (stale) content. For scratch the
    /// kernel fully overwrites before reading — column matrices, GEMM
    /// pack buffers, row accumulators — this skips the memset that
    /// [`ExecCtx::take`] pays on every checkout. Padded-input buffers
    /// must keep using the filling variant.
    pub fn take_unfilled(&self, len: usize) -> Vec<f32> {
        let mut buf = self.pick(len);
        let before = buf.capacity();
        if buf.len() > len {
            buf.truncate(len);
        } else {
            // Writes only the grown tail (nothing, when warm).
            buf.resize(len, 0.0);
        }
        if buf.capacity() > before {
            self.allocs.fetch_add(1, Ordering::Relaxed);
        }
        buf
    }

    /// Best-fit pick from the arena (or an empty vec when none fits).
    fn pick(&self, len: usize) -> Vec<f32> {
        let mut arena = self.arena.lock().unwrap();
        let pick = (0..arena.len())
            .filter(|&i| arena[i].capacity() >= len)
            .min_by_key(|&i| arena[i].capacity())
            .or_else(|| (0..arena.len()).max_by_key(|&i| arena[i].capacity()));
        match pick {
            Some(i) => arena.swap_remove(i),
            None => Vec::new(),
        }
    }

    /// Return a buffer taken with [`ExecCtx::take`] /
    /// [`ExecCtx::take_unfilled`] to the arena.
    pub fn put(&self, buf: Vec<f32>) {
        self.arena.lock().unwrap().push(buf);
    }

    /// Total `f32` capacity currently retained by the arena's free
    /// buffers. This is the memory a long-lived context pins between
    /// calls — the quantity [`ExecCtx::trim`] bounds and the
    /// coordinator's arena-retention knob caps after every batch.
    pub fn arena_floats(&self) -> usize {
        self.arena.lock().unwrap().iter().map(Vec::capacity).sum()
    }

    /// Drop cached buffers (largest first) until the arena holds at most
    /// `max_floats` elements of capacity. Bounds the high-water-mark
    /// memory a long-lived context retains; the legacy no-ctx entry
    /// points trim their shared per-thread context after every call.
    pub fn trim(&self, max_floats: usize) {
        let mut arena = self.arena.lock().unwrap();
        arena.sort_by_key(Vec::capacity);
        let mut total: usize = arena.iter().map(Vec::capacity).sum();
        while total > max_floats {
            match arena.pop() {
                Some(b) => total -= b.capacity(),
                None => break,
            }
        }
    }

    /// Run `body(item_index, item_slice)` for every `chunk`-sized item
    /// of `data`, fanning contiguous item ranges out over the ctx's
    /// worker threads.
    ///
    /// Every kernel's parallel loop is this call: `data` is the output
    /// tensor's storage, one item is one independently-computable unit
    /// (an output plane for 2-D kernels, an output row for 1-D, a group
    /// block for im2col+GEMM). Results are bit-identical for any thread
    /// count because the per-item computation never depends on the
    /// partition.
    ///
    /// # Panics
    /// If `chunk` is zero or does not divide `data.len()`.
    pub fn par_chunks(
        &self,
        data: &mut [f32],
        chunk: usize,
        body: impl Fn(usize, &mut [f32]) + Sync,
    ) {
        self.par_chunks_with(data, chunk, || (), |i, c, _s| body(i, c), |_s| {});
    }

    /// [`ExecCtx::par_chunks`] with worker-local state: each worker runs
    /// `init()` once before its items, threads the state `&mut` through
    /// `body`, and hands it to `fini` when its range is done.
    ///
    /// Kernels use the state for their scratch buffers (`init` takes
    /// from the arena, `fini` puts back), so a worker checks scratch out
    /// **once per parallel region**, not once per item — the number of
    /// live buffers equals the worker count, which keeps steady-state
    /// arena traffic deterministic and allocation-free.
    ///
    /// # Panics
    /// If `chunk` is zero or does not divide `data.len()`.
    pub fn par_chunks_with<S>(
        &self,
        data: &mut [f32],
        chunk: usize,
        init: impl Fn() -> S + Sync,
        body: impl Fn(usize, &mut [f32], &mut S) + Sync,
        fini: impl Fn(S) + Sync,
    ) {
        assert!(chunk > 0, "par_chunks needs a positive chunk size");
        assert_eq!(data.len() % chunk, 0, "data not a whole number of chunks");
        let items = data.len() / chunk;
        let workers = self.threads.min(items);
        if workers <= 1 {
            if items == 0 {
                return;
            }
            let mut state = init();
            for (i, c) in data.chunks_mut(chunk).enumerate() {
                body(i, c, &mut state);
            }
            fini(state);
            return;
        }
        // Contiguous balanced partition: first `rem` workers take one
        // extra item. Worker w's range starts where w-1's ended, so the
        // split points are pure arithmetic.
        let base = items / workers;
        let rem = items % workers;
        let init = &init;
        let body = &body;
        let fini = &fini;
        std::thread::scope(|s| {
            let mut rest = data;
            let mut start = 0usize;
            for w in 0..workers {
                let count = base + usize::from(w < rem);
                let (mine, tail) = rest.split_at_mut(count * chunk);
                rest = tail;
                let first = start;
                start += count;
                let run = move || {
                    // State never crosses threads: created, used and
                    // finalised on this worker (no `Send` bound on S).
                    let mut state = init();
                    for (j, c) in mine.chunks_mut(chunk).enumerate() {
                        body(first + j, c, &mut state);
                    }
                    fini(state);
                };
                if w + 1 == workers {
                    // Run the last range on the calling thread: one fewer
                    // spawn, and the scope still joins the rest.
                    run();
                } else {
                    s.spawn(run);
                }
            }
        });
    }
}

/// The number of hardware threads "use all threads" means, everywhere:
/// [`ExecCtx::auto`], the CLI's `--threads 0`, and the benches' multi-core
/// series all route through this one policy (1 when the machine won't say).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

thread_local! {
    static THREAD_CTX: RefCell<ExecCtx> = RefCell::new(ExecCtx::new(ConvAlgo::Sliding));
}

/// Run `f` against this thread's shared single-threaded context, with its
/// algorithm set to `algo`.
///
/// The legacy no-ctx kernel entry points (`conv2d`, `max_pool2d`, …)
/// route here, so repeated calls on one thread reuse padded/column/pack
/// scratch across calls instead of re-allocating per call. Re-entrant
/// use (a legacy call from inside another's `f`) falls back to a fresh
/// throwaway context rather than aliasing the shared one.
pub fn with_thread_ctx<R>(algo: ConvAlgo, f: impl FnOnce(&ExecCtx) -> R) -> R {
    /// Retention cap for the shared per-thread arena, in f32 elements
    /// (16 MiB): keeps the common scratch (column matrices, pack
    /// buffers, row accumulators) warm across legacy calls while one
    /// huge padded input can't stay pinned for the thread's lifetime.
    const LEGACY_ARENA_CAP: usize = 4 << 20;
    THREAD_CTX.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ctx) => {
            ctx.algo = algo;
            let r = f(&ctx);
            ctx.trim(LEGACY_ARENA_CAP);
            r
        }
        Err(_) => f(&ExecCtx::new(algo)),
    })
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::new(ConvAlgo::Sliding)
    }
}

impl Clone for ExecCtx {
    /// Clones algorithm, thread count and the (shared) dispatch profile
    /// with a fresh (empty) arena: the arena is a cache, not state —
    /// this is how each coordinator replica gets its own scratch while
    /// all replicas dispatch from one measured profile.
    fn clone(&self) -> Self {
        let mut c = ExecCtx::with_threads(self.algo, self.threads);
        c.profile = self.profile.clone();
        c
    }
}

impl fmt::Debug for ExecCtx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExecCtx")
            .field("algo", &self.algo)
            .field("threads", &self.threads)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_reuses_capacity() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let b = ctx.take(100, 1.5);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&v| v == 1.5));
        assert_eq!(ctx.alloc_events(), 1);
        ctx.put(b);
        // Same-size re-take: no new allocation, fully refilled.
        let b = ctx.take(64, -2.0);
        assert!(b.iter().all(|&v| v == -2.0));
        assert_eq!(ctx.alloc_events(), 1);
        ctx.put(b);
        // Growth is an alloc event.
        let b = ctx.take(10_000, 0.0);
        assert_eq!(ctx.alloc_events(), 2);
        ctx.put(b);
    }

    #[test]
    fn par_chunks_covers_every_item_once() {
        for threads in [1usize, 2, 3, 8] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
            let mut data = vec![0.0f32; 7 * 3];
            ctx.par_chunks(&mut data, 3, |i, c| {
                for v in c.iter_mut() {
                    *v += 1.0 + i as f32;
                }
            });
            for i in 0..7 {
                assert!(
                    data[i * 3..(i + 1) * 3].iter().all(|&v| v == 1.0 + i as f32),
                    "threads={threads} item {i}: {data:?}"
                );
            }
        }
    }

    #[test]
    fn par_chunks_more_threads_than_items() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Direct, 16);
        let mut data = vec![0.0f32; 2 * 5];
        ctx.par_chunks(&mut data, 5, |i, c| c.fill(i as f32));
        assert!(data[..5].iter().all(|&v| v == 0.0));
        assert!(data[5..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn par_chunks_empty_is_noop() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let mut data: Vec<f32> = Vec::new();
        ctx.par_chunks(&mut data, 4, |_, _| panic!("no items"));
    }

    #[test]
    fn workers_can_draw_scratch_concurrently() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let mut data = vec![0.0f32; 32];
        ctx.par_chunks(&mut data, 1, |i, c| {
            let mut s = ctx.take(16, i as f32);
            s[0] += 1.0;
            c[0] = s[0];
            ctx.put(s);
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as f32 + 1.0);
        }
    }

    #[test]
    fn trim_bounds_retained_capacity() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let big = ctx.take(1 << 20, 0.0);
        let small = ctx.take(1 << 10, 0.0);
        ctx.put(big);
        ctx.put(small);
        assert!(ctx.arena_floats() >= (1 << 20) + (1 << 10));
        ctx.trim(1 << 12);
        // The huge buffer is gone, the small one survives.
        assert!(ctx.arena_floats() <= 1 << 12);
        assert!(ctx.arena_floats() >= 1 << 10);
        ctx.trim(0);
        assert_eq!(ctx.arena_floats(), 0);
    }

    #[test]
    fn clone_keeps_config_fresh_arena() {
        let profile = Arc::new(DispatchProfile::paper_policy());
        let ctx =
            ExecCtx::with_threads(ConvAlgo::Im2colGemm, 3).with_profile(Arc::clone(&profile));
        let b = ctx.take(8, 0.0);
        ctx.put(b);
        let c2 = ctx.clone();
        assert_eq!(c2.algo, ConvAlgo::Im2colGemm);
        assert_eq!(c2.threads(), 3);
        assert_eq!(c2.alloc_events(), 0);
        assert!(
            c2.profile().is_some_and(|p| Arc::ptr_eq(p, &profile)),
            "replica clones must share the measured profile"
        );
    }

    #[test]
    fn tuned_lookups_fall_back_to_paper_policy() {
        let ctx = ExecCtx::new(ConvAlgo::Tuned);
        assert!(ctx.profile().is_none());
        assert_eq!(ctx.tuned_choice(5), (TunedAlgo::Sliding, RowKernel::Custom));
        assert_eq!(ctx.tuned_row_kernel(9), RowKernel::Generic);
        assert_eq!(ctx.tuned_row_kernel(30), RowKernel::Compound);
    }

    #[test]
    fn debug_is_compact() {
        let s = format!("{:?}", ExecCtx::with_threads(ConvAlgo::Sliding, 2));
        assert!(s.contains("Sliding") && s.contains("2"));
    }

    #[test]
    fn thread_ctx_reuses_scratch_across_legacy_calls() {
        // Each test runs on its own thread, so THREAD_CTX starts fresh.
        let before = with_thread_ctx(ConvAlgo::Direct, |ctx| {
            let b = ctx.take(128, 0.0);
            ctx.put(b);
            ctx.alloc_events()
        });
        let after = with_thread_ctx(ConvAlgo::Sliding, |ctx| {
            assert_eq!(ctx.algo, ConvAlgo::Sliding);
            let b = ctx.take(64, 0.0);
            ctx.put(b);
            ctx.alloc_events()
        });
        assert_eq!(after, before, "second legacy call must reuse scratch");
    }

    #[test]
    fn thread_ctx_reentrant_falls_back_to_fresh_ctx() {
        with_thread_ctx(ConvAlgo::Direct, |outer| {
            with_thread_ctx(ConvAlgo::Sliding, |inner| {
                assert_eq!(inner.algo, ConvAlgo::Sliding);
                assert_eq!(outer.algo, ConvAlgo::Direct);
            });
        });
    }
}
