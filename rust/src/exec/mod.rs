//! The execution-context subsystem: worker threads + a scratch-buffer
//! arena, threaded through every kernel layer.
//!
//! The paper's precursor (arXiv:2305.16513) stresses that sliding-window
//! kernels parallelize naturally across independent output rows, and
//! ZNNi (arXiv:1606.05688) shows CPU conv throughput is won by saturating
//! all cores while controlling working-set memory. [`ExecCtx`] is the
//! carrier for both:
//!
//! * **Threads** — [`ExecCtx::par_chunks`] fans independent work items
//!   (one output plane / row / group block each) out over `threads`
//!   workers. By default the ranges are submitted to a persistent,
//!   optionally core-pinned [`pool::WorkerPool`] (built lazily on first
//!   use, shared by [`ExecCtx::with_pool`] / `Clone`), so the small
//!   layers where sliding beats GEMM stop paying a thread spawn per
//!   parallel region; `SWCONV_NO_POOL=1` — or the CLI's `--no-pool` —
//!   restores the original spawn-per-region scoped threads
//!   ([`pool::set_pooling_disabled`]). Either way items are split into
//!   *contiguous* ranges so each worker owns a disjoint `&mut` window
//!   of the output, and every item is computed with exactly the same
//!   instruction sequence regardless of which worker runs it, so
//!   results are **bit-identical** for any thread count, pooled or
//!   scoped. The chunked data is generic over its element type (`f32`
//!   output planes, `i32` quantized accumulators, bf16 storage —
//!   anything `Send`).
//! * **Scratch arena** — [`ExecCtx::take_elems`]/[`ExecCtx::put_elems`]
//!   check reusable typed buffers (`Vec<f32>`, `Vec<i8>`, `Vec<i32>`,
//!   `Vec<Bf16>`, …) in and out of one shared free list, so the
//!   padded-input / row-accumulator / im2col-column buffers that every
//!   kernel needs — at every element width — are allocated once and
//!   reused across calls (the coordinator keeps one ctx per backend, so
//!   batched serving stops paying allocation churn per request).
//!   Retention accounting is **byte-based** ([`ExecCtx::arena_bytes`]),
//!   and [`ExecCtx::alloc_events`] counts buffer
//!   growths so tests can assert the steady state allocates nothing.
//!   [`ExecCtx::take`]/[`ExecCtx::put`] are the `f32` conveniences the
//!   pre-dtype kernels keep using, unchanged.
//!
//! `ExecCtx` also carries the convolution-algorithm choice
//! ([`ConvAlgo`]) that the per-request router switches, the element type
//! requests should be served in ([`ExecCtx::dtype`] — `f32` bit-exact by
//! default, bf16 or quantized int8 when asked), and, optionally, a
//! measured [`DispatchProfile`] ([`ExecCtx::with_profile`]) that the
//! tuned dispatch paths ([`ConvAlgo::Tuned`], `SlideVariant::Auto`)
//! consult instead of the paper's hard-coded k=17 crossover policy
//! (profile lookups are dtype- and ISA-aware; see
//! [`DispatchProfile::choice_at`]).
//!
//! Finally, the ctx pins the **instruction-set level** its kernels run
//! at ([`ExecCtx::isa`]): the machine's detected [`IsaLevel`] by
//! default, overridable per ctx ([`ExecCtx::with_isa`]) or globally
//! (the CLI's `--isa`, via [`IsaLevel::force`]). Every intrinsic
//! kernel is bit-identical to the portable one, so the level changes
//! throughput, never results.

pub mod affinity;
pub mod cache_topology;
pub mod pool;

pub use affinity::{numa_nodes, CoreSet};
pub use cache_topology::CacheInfo;
pub use pool::WorkerPool;

use crate::autotune::{DispatchProfile, TunedAlgo};
use crate::kernels::rowconv::RowKernel;
use crate::kernels::ConvAlgo;
use crate::simd::IsaLevel;
use crate::tensor::Dtype;
use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// One parked scratch buffer: a type-erased `Vec<T>` plus the metadata
/// the best-fit picker and the byte accounting need without downcasting.
struct ArenaSlot {
    /// `TypeId::of::<Vec<T>>()` — buffers only ever hand back to a
    /// matching `take_elems::<T>`.
    ty: TypeId,
    /// Retained capacity in bytes (`capacity * size_of::<T>()`).
    bytes: usize,
    /// Pool-worker slot that last returned this buffer (`None` off the
    /// pool). The picker prefers handing a worker its own buffers back,
    /// so pages a pinned worker first-touched stay on that worker's
    /// core/node. A pure locality hint — indices are per-pool, and a
    /// miss falls through to any fitting buffer.
    worker: Option<usize>,
    buf: Box<dyn Any + Send>,
}

/// The arena behind the mutex: parked buffers plus the last time any
/// buffer was checked in or out (what [`ExecCtx::trim_after_idle`]
/// compares against).
struct ArenaState {
    slots: Vec<ArenaSlot>,
    /// Buffers returned while a parallel region is active on this ctx:
    /// parked here — invisible to the picker — until the region ends.
    /// This makes the per-region checkout count *deterministic* (every
    /// range's `init` draws a distinct buffer, so one region = exactly
    /// `workers` checkouts per scratch kind), instead of depending on
    /// whether a fast worker's `fini` raced a slow worker's `init`; the
    /// zero-alloc steady state is then a guarantee, not a likelihood.
    deferred: Vec<ArenaSlot>,
    /// Parallel regions currently active on this ctx (the deferral
    /// window; normally 0 or 1).
    regions: usize,
    last_use: Instant,
}

/// RAII marker for one active parallel region: opens the put-deferral
/// window on construction, and on drop — panic included — closes it,
/// flushing the deferred buffers back to the free list.
struct RegionGuard<'a> {
    ctx: &'a ExecCtx,
}

impl<'a> RegionGuard<'a> {
    fn enter(ctx: &'a ExecCtx) -> Self {
        ctx.arena.lock().unwrap().regions += 1;
        RegionGuard { ctx }
    }
}

impl Drop for RegionGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.ctx.arena.lock().unwrap();
        st.regions -= 1;
        if st.regions == 0 {
            let mut deferred = std::mem::take(&mut st.deferred);
            st.slots.append(&mut deferred);
        }
    }
}

/// Per-request / per-backend execution context: algorithm selection,
/// element type, worker-thread count, the scratch-buffer arena and
/// (optionally) the machine's measured dispatch profile.
///
/// Cheap to construct; construct once and reuse to amortise scratch
/// allocations. Not `Copy` (it owns the arena) — build with
/// [`ExecCtx::new`] / [`ExecCtx::with_threads`] / [`ExecCtx::auto`].
///
/// # Examples
///
/// Serve the same workload single- and multi-threaded; results are
/// bit-identical and the second call reuses the first call's scratch:
///
/// ```
/// use swconv::exec::ExecCtx;
/// use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
/// use swconv::tensor::Tensor;
///
/// let x = Tensor::randn(&[1, 2, 16, 16], 1);
/// let w = Tensor::randn(&[4, 2, 3, 3], 2);
/// let p = Conv2dParams::same(3);
///
/// let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
/// let warm = conv2d_ctx(&x, &w, None, &p, &ctx);
/// let allocs = ctx.alloc_events();
/// let again = conv2d_ctx(&x, &w, None, &p, &ctx);
/// assert_eq!(warm.as_slice(), again.as_slice());
/// assert_eq!(ctx.alloc_events(), allocs, "steady state allocates nothing");
///
/// let one = ExecCtx::new(ConvAlgo::Sliding);
/// assert_eq!(conv2d_ctx(&x, &w, None, &p, &one).as_slice(), warm.as_slice());
/// ```
pub struct ExecCtx {
    /// Convolution algorithm for all conv layers routed through this ctx.
    pub algo: ConvAlgo,
    threads: usize,
    /// Temporary worker cap (0 = uncapped): [`ExecCtx::threads`] answers
    /// `min(threads, cap)` while a cap is set. The planned executor sets
    /// it around a single node's kernels when the plan chose a narrower
    /// split than the ctx-wide count ([`crate::graph::PlannedChoice`]);
    /// results are bit-identical for any thread count, so the cap is a
    /// pure performance/footprint knob.
    thread_cap: AtomicUsize,
    dtype: Dtype,
    /// Instruction-set level the kernels dispatch at; defaults to the
    /// process-wide effective level ([`IsaLevel::effective`]).
    isa: IsaLevel,
    arena: Mutex<ArenaState>,
    allocs: AtomicUsize,
    /// Measured dispatch profile, shared across replicas via `Arc`;
    /// `None` means every tuned lookup answers with the paper policy.
    profile: Option<Arc<DispatchProfile>>,
    /// How this ctx runs parallel regions, resolved at most once:
    /// unset → decide lazily on the first multi-worker region (build a
    /// persistent [`WorkerPool`] unless pooling is disabled);
    /// `Some(pool)` → submit to that pool; `None` → scoped threads,
    /// explicitly ([`ExecCtx::without_pool`] or a disabled resolution).
    pool: OnceLock<Option<Arc<WorkerPool>>>,
}

impl ExecCtx {
    /// Single-threaded context with the given algorithm (the exact
    /// behaviour of the pre-subsystem `ExecCtx { algo }`).
    pub fn new(algo: ConvAlgo) -> Self {
        Self::with_threads(algo, 1)
    }

    /// Context with an explicit worker-thread count (clamped to ≥ 1).
    pub fn with_threads(algo: ConvAlgo, threads: usize) -> Self {
        ExecCtx {
            algo,
            threads: threads.max(1),
            thread_cap: AtomicUsize::new(0),
            dtype: Dtype::F32,
            isa: IsaLevel::effective(),
            arena: Mutex::new(ArenaState {
                slots: Vec::new(),
                deferred: Vec::new(),
                regions: 0,
                last_use: Instant::now(),
            }),
            allocs: AtomicUsize::new(0),
            profile: None,
            pool: OnceLock::new(),
        }
    }

    /// Context using every available hardware thread
    /// (see [`available_threads`]).
    pub fn auto(algo: ConvAlgo) -> Self {
        Self::with_threads(algo, available_threads())
    }

    /// Attach a measured dispatch profile (builder style). The tuned
    /// dispatch paths — [`ConvAlgo::Tuned`] and the sliding kernel's
    /// `Auto` row selection — consult it via [`ExecCtx::tuned_choice`] /
    /// [`ExecCtx::tuned_row_kernel`]; without one they answer with the
    /// paper's §2 policy.
    pub fn with_profile(mut self, profile: Arc<DispatchProfile>) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Set the element type this context serves in (builder style).
    /// `Dtype::F32` — the default — is the pre-dtype behaviour bit for
    /// bit; `Bf16`/`I8` make dtype-aware layers ([`crate::nn`]'s
    /// `Conv2d`, `QuantizedConv2d`) run the reduced-precision kernels
    /// with quantize/dequantize at layer boundaries.
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Install (or replace) the element type on an existing context —
    /// what the coordinator does to each replica's backend right after
    /// construction for a `BackendSpec::with_dtype` tier.
    pub fn set_dtype(&mut self, dtype: Dtype) {
        self.dtype = dtype;
    }

    /// The element type this context serves in.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Pin the instruction-set level this context dispatches at
    /// (builder style). The level must hold [`IsaLevel::available`] on
    /// this machine for the intrinsic paths to actually run — the safe
    /// kernel wrappers re-check availability and fall back to the
    /// portable kernels otherwise, so an impossible level degrades to
    /// scalar rather than faulting. `IsaLevel::Scalar` forces the
    /// portable [`crate::simd::F32xL`] kernels, which is what the
    /// parity tests diff every other level against.
    pub fn with_isa(mut self, isa: IsaLevel) -> Self {
        self.isa = isa;
        self
    }

    /// Install (or replace) the instruction-set level on an existing
    /// context.
    pub fn set_isa(&mut self, isa: IsaLevel) {
        self.isa = isa;
    }

    /// The instruction-set level this context dispatches kernels at.
    pub fn isa(&self) -> IsaLevel {
        self.isa
    }

    /// Run parallel regions on the given persistent [`WorkerPool`]
    /// (builder style). Without this, a multi-threaded ctx builds its
    /// own pool lazily on the first parallel region — `with_pool` is for
    /// sharing one pool between contexts, or installing a core-pinned
    /// one ([`WorkerPool::pinned`]).
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.set_pool(Some(pool));
        self
    }

    /// Opt this context out of persistent pooling (builder style): every
    /// parallel region spawns scoped threads, the pre-pool behaviour bit
    /// for bit. The overhead bench uses this as its baseline; the
    /// `--no-pool` CLI flag and `SWCONV_NO_POOL=1` apply the same
    /// fallback globally ([`pool::set_pooling_disabled`]).
    pub fn without_pool(mut self) -> Self {
        self.set_pool(None);
        self
    }

    /// Install (`Some`) or remove (`None`) the worker pool on an
    /// existing context, replacing any earlier — or lazily made —
    /// choice. This is how a coordinator replica swaps its cloned ctx
    /// onto a pool pinned to the replica's own core slice.
    pub fn set_pool(&mut self, pool: Option<Arc<WorkerPool>>) {
        let cell = OnceLock::new();
        let _ = cell.set(pool);
        self.pool = cell;
    }

    /// The persistent pool this context runs on, if one has been
    /// attached or lazily resolved. `None` both before the first
    /// parallel region (nothing resolved yet) and when the ctx runs
    /// scoped threads.
    pub fn pool_handle(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.get().and_then(|p| p.as_ref())
    }

    /// Resolve the pooling decision (at most once per ctx): an attached
    /// pool wins; otherwise build a `threads - 1`-worker pool — the
    /// caller runs the last range itself — unless pooling is globally
    /// disabled.
    fn resolve_pool(&self) -> Option<Arc<WorkerPool>> {
        self.pool
            .get_or_init(|| {
                if self.threads <= 1 || pool::pooling_disabled() {
                    None
                } else {
                    Some(WorkerPool::new(self.threads - 1))
                }
            })
            .clone()
    }

    /// Install (or replace) the dispatch profile on an existing context
    /// — what [`crate::coordinator::BackendSpec::with_profile`] does to
    /// each replica's backend right after construction.
    pub fn set_profile(&mut self, profile: Arc<DispatchProfile>) {
        self.profile = Some(profile);
    }

    /// The attached dispatch profile, if any.
    pub fn profile(&self) -> Option<&Arc<DispatchProfile>> {
        self.profile.as_ref()
    }

    /// Tuned `(conv-level algorithm, row family)` for filter width `k`
    /// at this ctx's thread count **and dtype**: the profile's
    /// nearest-bucket answer among entries measured for this dtype, or
    /// the paper policy when no profile (or no matching-dtype bucket) is
    /// attached. Always legal — see [`DispatchProfile::choice_for`] for
    /// the clamping rules.
    pub fn tuned_choice(&self, k: usize) -> (TunedAlgo, RowKernel) {
        self.tuned_choice_for(k, self.dtype)
    }

    /// [`ExecCtx::tuned_choice`] with an explicit element type,
    /// overriding the ctx's own dtype. The reduced-precision boundary
    /// wrappers use this: a `QuantizedConv2d` layer always runs int8
    /// regardless of the ctx's serving dtype, so its `Tuned` routing
    /// must consult the `I8` buckets even under a `F32` ctx.
    pub fn tuned_choice_for(&self, k: usize, dtype: Dtype) -> (TunedAlgo, RowKernel) {
        match &self.profile {
            Some(p) => p.choice_at(k, self.threads, dtype, self.isa),
            None => DispatchProfile::paper_policy().choice_at(k, self.threads, dtype, self.isa),
        }
    }

    /// The tuned row-kernel family for width `k` (the
    /// [`ExecCtx::tuned_choice`] slide component): what
    /// `SlideVariant::Auto` runs per row.
    pub fn tuned_row_kernel(&self, k: usize) -> RowKernel {
        self.tuned_choice(k).1
    }

    /// Worker-thread count the next parallel region fans out to: the
    /// configured count, narrowed by the active cap when one is set
    /// ([`ExecCtx::set_thread_cap`]).
    pub fn threads(&self) -> usize {
        match self.thread_cap.load(Ordering::Relaxed) {
            0 => self.threads,
            cap => self.threads.min(cap),
        }
    }

    /// Set (non-zero) or clear (0) the temporary worker cap. The ctx's
    /// configured thread count — and the pool built from it — is
    /// untouched; only how many workers the next regions use changes.
    /// Partitioning is deterministic per worker count, so capping keeps
    /// results bit-identical while shrinking the number of concurrently
    /// live scratch buffers — the lever the whole-model planner pulls
    /// per node ([`crate::graph::ModelPlan`]).
    pub fn set_thread_cap(&self, cap: usize) {
        self.thread_cap.store(cap, Ordering::Relaxed);
    }

    /// Number of scratch-buffer allocations (or capacity growths) so
    /// far. Steady-state kernel calls must not move this counter — the
    /// arena-reuse tests assert exactly that.
    pub fn alloc_events(&self) -> usize {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Check a typed buffer of `len` elements, every element set to
    /// `fill`, out of the arena; return it with [`ExecCtx::put_elems`]
    /// when done. This is the dtype-generic workhorse behind
    /// [`ExecCtx::take`]; the quantized kernels draw their `i8` padded
    /// inputs and `i32` accumulators from the same arena as the f32
    /// kernels draw theirs.
    ///
    /// Best-fit reuse *per element type*: the smallest free buffer of
    /// this type whose capacity already holds `len`, else the largest
    /// available (which grows once and then keeps its capacity).
    /// Best-fit keeps small requests from stealing large buffers, so a
    /// warmed arena serves a repeating workload with zero allocations in
    /// any take order.
    pub fn take_elems<T: Copy + Send + 'static>(&self, len: usize, fill: T) -> Vec<T> {
        let mut buf = self.pick::<T>(len);
        let before = buf.capacity();
        buf.clear();
        buf.resize(len, fill);
        if buf.capacity() > before {
            self.allocs.fetch_add(1, Ordering::Relaxed);
        }
        buf
    }

    /// [`ExecCtx::take_elems`] without the refill: the buffer has `len`
    /// elements of **unspecified** (stale) content. For scratch the
    /// kernel fully overwrites before reading — column matrices, GEMM
    /// pack buffers, row accumulators — this skips the memset that
    /// the filling variant pays on every checkout. Padded-input buffers
    /// must keep using the filling variant.
    pub fn take_elems_unfilled<T: Copy + Default + Send + 'static>(&self, len: usize) -> Vec<T> {
        let mut buf = self.pick::<T>(len);
        let before = buf.capacity();
        if buf.len() > len {
            buf.truncate(len);
        } else {
            // Writes only the grown tail (nothing, when warm).
            buf.resize(len, T::default());
        }
        if buf.capacity() > before {
            self.allocs.fetch_add(1, Ordering::Relaxed);
        }
        buf
    }

    /// Best-fit pick from the arena's same-typed slots (or an empty vec
    /// when none fits). A pool worker's own returned buffers are
    /// preferred over equally-fitting ones, so first-touched pages keep
    /// coming back to the core that touched them; the fallbacks are
    /// unchanged, so the preference can change locality but never
    /// whether a warm arena re-allocates.
    fn pick<T: Copy + Send + 'static>(&self, len: usize) -> Vec<T> {
        let want = len.saturating_mul(std::mem::size_of::<T>());
        let ty = TypeId::of::<Vec<T>>();
        let me = pool::current_worker_slot();
        let mut st = self.arena.lock().unwrap();
        st.last_use = Instant::now();
        let slots = &st.slots;
        let fits = |i: usize| slots[i].ty == ty && slots[i].bytes >= want;
        let pick = (0..slots.len())
            .filter(|&i| fits(i) && slots[i].worker == me)
            .min_by_key(|&i| slots[i].bytes)
            .or_else(|| (0..slots.len()).filter(|&i| fits(i)).min_by_key(|&i| slots[i].bytes))
            .or_else(|| {
                (0..slots.len()).filter(|&i| slots[i].ty == ty).max_by_key(|&i| slots[i].bytes)
            });
        match pick {
            Some(i) => *st.slots.swap_remove(i).buf.downcast::<Vec<T>>().expect("slot type tag"),
            None => Vec::new(),
        }
    }

    /// Return a buffer taken with [`ExecCtx::take_elems`] /
    /// [`ExecCtx::take_elems_unfilled`] (or the `f32` conveniences) to
    /// the arena.
    pub fn put_elems<T: Copy + Send + 'static>(&self, buf: Vec<T>) {
        let bytes = buf.capacity().saturating_mul(std::mem::size_of::<T>());
        let slot = ArenaSlot {
            ty: TypeId::of::<Vec<T>>(),
            bytes,
            worker: pool::current_worker_slot(),
            buf: Box::new(buf),
        };
        let mut st = self.arena.lock().unwrap();
        st.last_use = Instant::now();
        if st.regions > 0 {
            // Mid-region returns park aside so concurrent ranges never
            // reuse each other's buffers (see `ArenaState::deferred`).
            st.deferred.push(slot);
        } else {
            st.slots.push(slot);
        }
    }

    /// [`ExecCtx::take_elems`] for `f32` — the convenience every
    /// pre-dtype kernel keeps calling.
    pub fn take(&self, len: usize, fill: f32) -> Vec<f32> {
        self.take_elems(len, fill)
    }

    /// [`ExecCtx::take_elems_unfilled`] for `f32`.
    pub fn take_unfilled(&self, len: usize) -> Vec<f32> {
        self.take_elems_unfilled(len)
    }

    /// [`ExecCtx::put_elems`] for `f32`.
    pub fn put(&self, buf: Vec<f32>) {
        self.put_elems(buf)
    }

    /// Total capacity in **bytes** currently retained by the arena's
    /// free buffers, across every element type. This is the memory a
    /// long-lived context pins between calls — the quantity
    /// [`ExecCtx::trim_bytes`] bounds and the coordinator's
    /// arena-retention knobs cap after every batch / idle period.
    pub fn arena_bytes(&self) -> usize {
        self.arena.lock().unwrap().slots.iter().map(|s| s.bytes).sum()
    }

    /// Drop cached buffers (largest first, any element type) until the
    /// arena holds at most `max_bytes` bytes of capacity. Bounds the
    /// high-water-mark memory a long-lived context retains; the legacy
    /// no-ctx entry points trim their shared per-thread context after
    /// every call.
    pub fn trim_bytes(&self, max_bytes: usize) {
        let mut st = self.arena.lock().unwrap();
        st.slots.sort_by_key(|s| s.bytes);
        let mut total: usize = st.slots.iter().map(|s| s.bytes).sum();
        while total > max_bytes {
            match st.slots.pop() {
                Some(s) => total -= s.bytes,
                None => break,
            }
        }
    }

    /// [`ExecCtx::trim_bytes`] with an `f32`-denominated cap (the
    /// coordinator's historical `--trim-mb` unit: `max_floats` × 4
    /// bytes).
    pub fn trim(&self, max_floats: usize) {
        self.trim_bytes(max_floats.saturating_mul(std::mem::size_of::<f32>()));
    }

    /// Time-based retention: drop **all** cached buffers if the arena
    /// has not been touched (no take/put) for at least `idle`. Returns
    /// whether anything was freed. This is the serving-tier
    /// trim-after-idle knob — a backend that has gone quiet releases its
    /// scratch instead of pinning the last burst's high-water mark; the
    /// next request simply re-allocates (one `alloc_event`, then steady
    /// state again). Checking the idle clock does not itself count as a
    /// use.
    pub fn trim_after_idle(&self, idle: Duration) -> bool {
        let mut st = self.arena.lock().unwrap();
        if st.last_use.elapsed() < idle || st.slots.is_empty() {
            return false;
        }
        st.slots.clear();
        true
    }

    /// Run `body(item_index, item_slice)` for every `chunk`-sized item
    /// of `data`, fanning contiguous item ranges out over the ctx's
    /// worker threads.
    ///
    /// Every kernel's parallel loop is this call: `data` is the output
    /// tensor's storage — any `Send` element type: `f32` planes, `i32`
    /// quantized accumulators, bf16 rows — one item is one
    /// independently-computable unit (an output plane for 2-D kernels,
    /// an output row for 1-D, a group block for im2col+GEMM). Results
    /// are bit-identical for any thread count because the per-item
    /// computation never depends on the partition.
    ///
    /// # Panics
    /// If `chunk` is zero or does not divide `data.len()`.
    pub fn par_chunks<T: Send>(
        &self,
        data: &mut [T],
        chunk: usize,
        body: impl Fn(usize, &mut [T]) + Sync,
    ) {
        self.par_chunks_with(data, chunk, || (), |i, c, _s| body(i, c), |_s| {});
    }

    /// [`ExecCtx::par_chunks`] with worker-local state: each worker runs
    /// `init()` once before its items, threads the state `&mut` through
    /// `body`, and hands it to `fini` when its range is done.
    ///
    /// Kernels use the state for their scratch buffers (`init` takes
    /// from the arena, `fini` puts back), so a worker checks scratch out
    /// **once per parallel region**, not once per item — the number of
    /// live buffers equals the worker count, which keeps steady-state
    /// arena traffic deterministic and allocation-free.
    ///
    /// Ranges run on the ctx's persistent [`WorkerPool`] by default
    /// (scoped threads when pooling is disabled — the partition, and
    /// therefore every result bit, is identical either way). A region
    /// opened from inside a pool worker — a kernel calling a kernel —
    /// runs inline on that worker, so nesting cannot deadlock.
    ///
    /// # Panics
    /// If `chunk` is zero or does not divide `data.len()`. A panic in
    /// any chunk body propagates to this caller once the region has
    /// drained; pool workers survive it (the panic poisons only the
    /// region, not the pool).
    pub fn par_chunks_with<T: Send, S>(
        &self,
        data: &mut [T],
        chunk: usize,
        init: impl Fn() -> S + Sync,
        body: impl Fn(usize, &mut [T], &mut S) + Sync,
        fini: impl Fn(S) + Sync,
    ) {
        assert!(chunk > 0, "par_chunks needs a positive chunk size");
        assert_eq!(data.len() % chunk, 0, "data not a whole number of chunks");
        let items = data.len() / chunk;
        let workers = self.threads().min(items);
        if workers <= 1 || pool::on_pool_worker() {
            if items == 0 {
                return;
            }
            let mut state = init();
            for (i, c) in data.chunks_mut(chunk).enumerate() {
                body(i, c, &mut state);
            }
            fini(state);
            return;
        }
        // Contiguous balanced partition: first `rem` workers take one
        // extra item. Worker w's range starts where w-1's ended, so the
        // split points are pure arithmetic — identical for the pooled
        // and scoped paths, which is what keeps them bit-identical.
        let base = items / workers;
        let rem = items % workers;
        // Deterministic scratch accounting for the whole region: puts
        // issued while this guard lives are deferred, so each range's
        // `init` checks out a distinct buffer no matter how the ranges
        // interleave in time (exactly `workers` checkouts per kind).
        let _region = RegionGuard::enter(self);
        if let Some(pool) = self.resolve_pool() {
            let ptr = SendPtr(data.as_mut_ptr());
            let run = move |w: usize| {
                let first = w * base + w.min(rem);
                let count = base + usize::from(w < rem);
                // SAFETY: ranges are pairwise disjoint by the partition
                // arithmetic, `T: Send` lets the slice cross to a pool
                // worker, and `run_region` does not return until every
                // range is done — so each worker holds the only live
                // reference to its window of `data`.
                let mine = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0.add(first * chunk), count * chunk)
                };
                // State never crosses threads: created, used and
                // finalised within this range (no `Send` bound on S).
                let mut state = init();
                for (j, c) in mine.chunks_mut(chunk).enumerate() {
                    body(first + j, c, &mut state);
                }
                fini(state);
            };
            pool.run_region(workers, &run);
            return;
        }
        let init = &init;
        let body = &body;
        let fini = &fini;
        std::thread::scope(|s| {
            let mut rest = data;
            let mut start = 0usize;
            for w in 0..workers {
                let count = base + usize::from(w < rem);
                let (mine, tail) = rest.split_at_mut(count * chunk);
                rest = tail;
                let first = start;
                start += count;
                let run = move || {
                    // State never crosses threads: created, used and
                    // finalised on this worker (no `Send` bound on S).
                    let mut state = init();
                    for (j, c) in mine.chunks_mut(chunk).enumerate() {
                        body(first + j, c, &mut state);
                    }
                    fini(state);
                };
                if w + 1 == workers {
                    // Run the last range on the calling thread: one fewer
                    // spawn, and the scope still joins the rest.
                    run();
                } else {
                    s.spawn(run);
                }
            }
        });
    }
}

/// A raw pointer that may cross threads: the pooled `par_chunks` path
/// derives pairwise-disjoint `&mut` range windows from it on the pool
/// workers.
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

// SAFETY: only ever dereferenced through disjoint ranges whose lifetime
// is bounded by the region (see the safety comment at the use site);
// sending/sharing the *pointer value* is then as safe as `&mut [T]`
// itself, which requires `T: Send`.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// The number of hardware threads "use all threads" means, everywhere:
/// [`ExecCtx::auto`], the CLI's `--threads 0`, and the benches' multi-core
/// series all route through this one policy (1 when the machine won't say).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

thread_local! {
    static THREAD_CTX: RefCell<ExecCtx> = RefCell::new(ExecCtx::new(ConvAlgo::Sliding));
}

/// Run `f` against this thread's shared single-threaded context, with its
/// algorithm set to `algo`.
///
/// The legacy no-ctx kernel entry points (`conv2d`, `max_pool2d`, …)
/// route here, so repeated calls on one thread reuse padded/column/pack
/// scratch across calls instead of re-allocating per call. Re-entrant
/// use (a legacy call from inside another's `f`) falls back to a fresh
/// throwaway context rather than aliasing the shared one.
pub fn with_thread_ctx<R>(algo: ConvAlgo, f: impl FnOnce(&ExecCtx) -> R) -> R {
    /// Retention cap for the shared per-thread arena, in bytes (16 MiB):
    /// keeps the common scratch (column matrices, pack buffers, row
    /// accumulators) warm across legacy calls while one huge padded
    /// input can't stay pinned for the thread's lifetime.
    const LEGACY_ARENA_CAP_BYTES: usize = 16 << 20;
    THREAD_CTX.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ctx) => {
            ctx.algo = algo;
            let r = f(&ctx);
            ctx.trim_bytes(LEGACY_ARENA_CAP_BYTES);
            r
        }
        Err(_) => f(&ExecCtx::new(algo)),
    })
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::new(ConvAlgo::Sliding)
    }
}

impl Clone for ExecCtx {
    /// Clones algorithm, thread count, dtype, ISA level, the (shared)
    /// dispatch profile and the (shared) worker pool with a fresh
    /// (empty) arena:
    /// the arena is a cache, not state — this is how each coordinator
    /// replica gets its own scratch while all replicas dispatch from one
    /// measured profile. The pool is shared only once *resolved*
    /// (attached explicitly or created by a first parallel region); a
    /// never-used prototype ctx clones into replicas that each lazily
    /// build — and pin — their own pool.
    fn clone(&self) -> Self {
        let mut c = ExecCtx::with_threads(self.algo, self.threads);
        c.dtype = self.dtype;
        c.isa = self.isa;
        c.profile = self.profile.clone();
        if let Some(choice) = self.pool.get() {
            let _ = c.pool.set(choice.clone());
        }
        c
    }
}

impl fmt::Debug for ExecCtx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExecCtx")
            .field("algo", &self.algo)
            .field("dtype", &self.dtype)
            .field("threads", &self.threads)
            .field("isa", &self.isa)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Bf16;

    #[test]
    fn take_put_reuses_capacity() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let b = ctx.take(100, 1.5);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&v| v == 1.5));
        assert_eq!(ctx.alloc_events(), 1);
        ctx.put(b);
        // Same-size re-take: no new allocation, fully refilled.
        let b = ctx.take(64, -2.0);
        assert!(b.iter().all(|&v| v == -2.0));
        assert_eq!(ctx.alloc_events(), 1);
        ctx.put(b);
        // Growth is an alloc event.
        let b = ctx.take(10_000, 0.0);
        assert_eq!(ctx.alloc_events(), 2);
        ctx.put(b);
    }

    #[test]
    fn arena_is_dtype_generic_and_typed_buffers_do_not_mix() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let q: Vec<i8> = ctx.take_elems(256, 7i8);
        assert!(q.iter().all(|&v| v == 7));
        let acc: Vec<i32> = ctx.take_elems_unfilled(64);
        assert_eq!(acc.len(), 64);
        let h: Vec<Bf16> = ctx.take_elems(32, Bf16::from_f32(1.0));
        assert_eq!(ctx.alloc_events(), 3);
        ctx.put_elems(q);
        ctx.put_elems(acc);
        ctx.put_elems(h);
        // 256 i8 + 64 i32 + 32 bf16 = 256 + 256 + 64 bytes retained.
        assert!(ctx.arena_bytes() >= 256 + 256 + 64);
        // An f32 take must NOT hand back the i8 buffer's storage: it
        // allocates fresh (4th event) while same-typed re-takes reuse.
        let f: Vec<f32> = ctx.take_elems(16, 0.0f32);
        assert_eq!(ctx.alloc_events(), 4);
        ctx.put_elems(f);
        let q2: Vec<i8> = ctx.take_elems(100, 0i8);
        assert_eq!(ctx.alloc_events(), 4, "warm i8 buffer is reused");
        ctx.put_elems(q2);
    }

    #[test]
    fn par_chunks_covers_every_item_once() {
        for threads in [1usize, 2, 3, 8] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
            let mut data = vec![0.0f32; 7 * 3];
            ctx.par_chunks(&mut data, 3, |i, c| {
                for v in c.iter_mut() {
                    *v += 1.0 + i as f32;
                }
            });
            for i in 0..7 {
                assert!(
                    data[i * 3..(i + 1) * 3].iter().all(|&v| v == 1.0 + i as f32),
                    "threads={threads} item {i}: {data:?}"
                );
            }
        }
    }

    #[test]
    fn par_chunks_is_generic_over_the_element() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let mut data = vec![0i32; 6 * 2];
        ctx.par_chunks(&mut data, 2, |i, c| c.fill(i as i32 * 10));
        for i in 0..6 {
            assert!(data[i * 2..(i + 1) * 2].iter().all(|&v| v == i as i32 * 10));
        }
    }

    #[test]
    fn par_chunks_more_threads_than_items() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Direct, 16);
        let mut data = vec![0.0f32; 2 * 5];
        ctx.par_chunks(&mut data, 5, |i, c| c.fill(i as f32));
        assert!(data[..5].iter().all(|&v| v == 0.0));
        assert!(data[5..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn par_chunks_empty_is_noop() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let mut data: Vec<f32> = Vec::new();
        ctx.par_chunks(&mut data, 4, |_, _| panic!("no items"));
    }

    #[test]
    fn workers_can_draw_scratch_concurrently() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let mut data = vec![0.0f32; 32];
        ctx.par_chunks(&mut data, 1, |i, c| {
            let mut s = ctx.take(16, i as f32);
            s[0] += 1.0;
            c[0] = s[0];
            ctx.put(s);
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as f32 + 1.0);
        }
    }

    #[test]
    fn trim_bounds_retained_capacity() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let big = ctx.take(1 << 20, 0.0);
        let small = ctx.take(1 << 10, 0.0);
        ctx.put(big);
        ctx.put(small);
        assert!(ctx.arena_bytes() >= 4 * ((1 << 20) + (1 << 10)));
        ctx.trim(1 << 12);
        // The huge buffer is gone, the small one survives.
        assert!(ctx.arena_bytes() <= 4 << 12);
        assert!(ctx.arena_bytes() >= 4 << 10);
        ctx.trim_bytes(0);
        assert_eq!(ctx.arena_bytes(), 0);
    }

    #[test]
    fn trim_after_idle_frees_only_after_the_idle_gap() {
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let b = ctx.take(4096, 0.0);
        ctx.put(b);
        assert!(ctx.arena_bytes() > 0);
        // Fresh use: a long idle threshold must not trim.
        assert!(!ctx.trim_after_idle(Duration::from_secs(3600)));
        assert!(ctx.arena_bytes() > 0);
        // Checking the clock is not a use, so a zero threshold trims.
        assert!(ctx.trim_after_idle(Duration::ZERO));
        assert_eq!(ctx.arena_bytes(), 0);
        // Nothing retained: reports false.
        assert!(!ctx.trim_after_idle(Duration::ZERO));
    }

    #[test]
    fn clone_keeps_config_fresh_arena() {
        let profile = Arc::new(DispatchProfile::paper_policy());
        let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 3)
            .with_dtype(Dtype::I8)
            .with_profile(Arc::clone(&profile));
        let b = ctx.take(8, 0.0);
        ctx.put(b);
        let c2 = ctx.clone();
        assert_eq!(c2.algo, ConvAlgo::Im2colGemm);
        assert_eq!(c2.threads(), 3);
        assert_eq!(c2.dtype(), Dtype::I8);
        assert_eq!(c2.alloc_events(), 0);
        assert!(
            c2.profile().is_some_and(|p| Arc::ptr_eq(p, &profile)),
            "replica clones must share the measured profile"
        );
    }

    #[test]
    fn tuned_lookups_fall_back_to_paper_policy() {
        let ctx = ExecCtx::new(ConvAlgo::Tuned);
        assert!(ctx.profile().is_none());
        assert_eq!(ctx.dtype(), Dtype::F32);
        assert_eq!(ctx.tuned_choice(5), (TunedAlgo::Sliding, RowKernel::Custom));
        assert_eq!(ctx.tuned_row_kernel(9), RowKernel::Generic);
        assert_eq!(ctx.tuned_row_kernel(30), RowKernel::Compound);
        // A non-f32 dtype with no measured buckets also answers with the
        // paper policy rather than borrowing f32 buckets.
        let qctx = ExecCtx::new(ConvAlgo::Tuned).with_dtype(Dtype::I8);
        assert_eq!(qctx.tuned_choice(5), (TunedAlgo::Sliding, RowKernel::Custom));
    }

    #[test]
    fn thread_cap_narrows_regions_without_changing_results() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        assert_eq!(ctx.threads(), 4);
        ctx.set_thread_cap(2);
        assert_eq!(ctx.threads(), 2);
        let mut capped = vec![0.0f32; 12];
        ctx.par_chunks(&mut capped, 3, |i, c| c.fill(i as f32 + 1.0));
        ctx.set_thread_cap(0);
        assert_eq!(ctx.threads(), 4, "cap 0 clears");
        let mut full = vec![0.0f32; 12];
        ctx.par_chunks(&mut full, 3, |i, c| c.fill(i as f32 + 1.0));
        assert_eq!(capped, full, "capping must not change results");
        // A cap above the configured count is a no-op, and clones start
        // uncapped regardless of the source's cap.
        ctx.set_thread_cap(99);
        assert_eq!(ctx.threads(), 4);
        assert_eq!(ctx.clone().threads(), 4);
    }

    #[test]
    fn debug_is_compact() {
        let s = format!("{:?}", ExecCtx::with_threads(ConvAlgo::Sliding, 2));
        assert!(s.contains("Sliding") && s.contains("2") && s.contains("F32"));
    }

    #[test]
    fn thread_ctx_reuses_scratch_across_legacy_calls() {
        // Each test runs on its own thread, so THREAD_CTX starts fresh.
        let before = with_thread_ctx(ConvAlgo::Direct, |ctx| {
            let b = ctx.take(128, 0.0);
            ctx.put(b);
            ctx.alloc_events()
        });
        let after = with_thread_ctx(ConvAlgo::Sliding, |ctx| {
            assert_eq!(ctx.algo, ConvAlgo::Sliding);
            let b = ctx.take(64, 0.0);
            ctx.put(b);
            ctx.alloc_events()
        });
        assert_eq!(after, before, "second legacy call must reuse scratch");
    }

    #[test]
    fn thread_ctx_reentrant_falls_back_to_fresh_ctx() {
        with_thread_ctx(ConvAlgo::Direct, |outer| {
            with_thread_ctx(ConvAlgo::Sliding, |inner| {
                assert_eq!(inner.algo, ConvAlgo::Sliding);
                assert_eq!(outer.algo, ConvAlgo::Direct);
            });
        });
    }

    #[test]
    fn attached_pool_runs_regions_and_is_shared_by_clone() {
        let p = WorkerPool::new(2);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 3).with_pool(Arc::clone(&p));
        let mut data = vec![0.0f32; 9];
        ctx.par_chunks(&mut data, 3, |i, c| c.fill(i as f32 + 1.0));
        for i in 0..3 {
            assert!(data[i * 3..(i + 1) * 3].iter().all(|&v| v == i as f32 + 1.0));
        }
        let c2 = ctx.clone();
        assert!(
            c2.pool_handle().is_some_and(|q| Arc::ptr_eq(q, &p)),
            "clone must share an attached pool"
        );
        // An explicitly scoped ctx resolves to no pool, and its clone
        // inherits that choice.
        let scoped = ExecCtx::with_threads(ConvAlgo::Sliding, 3).without_pool();
        let mut d2 = vec![0.0f32; 9];
        scoped.par_chunks(&mut d2, 3, |i, c| c.fill(i as f32 + 1.0));
        assert_eq!(d2, data);
        assert!(scoped.pool_handle().is_none());
        assert!(scoped.clone().pool_handle().is_none());
    }

    // The process-global pooling flag is exercised by
    // `tests/pool_flag.rs` — its own integration binary, hence its own
    // process, so flipping the flag cannot race any lib test's lazy
    // pool resolution.

    #[test]
    fn nested_par_chunks_runs_inline_without_deadlock() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
        let inner_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
        let mut data = vec![0.0f32; 6 * 4];
        ctx.par_chunks(&mut data, 4, |i, c| {
            // A parallel region from inside a pool worker: must run
            // inline (sequentially) rather than re-entering a pool.
            inner_ctx.par_chunks(c, 1, |j, v| v.fill((i * 10 + j) as f32));
        });
        for i in 0..6 {
            for j in 0..4 {
                assert_eq!(data[i * 4 + j], (i * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn pool_panic_poisons_region_not_ctx() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 3).with_pool(WorkerPool::new(2));
        let mut data = vec![0.0f32; 8];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ctx.par_chunks(&mut data, 1, |i, _c| {
                if i == 5 {
                    panic!("item 5 exploded");
                }
            });
        }));
        assert!(r.is_err(), "chunk panic must reach the submitter");
        // The ctx (and its pool) keep serving.
        let mut again = vec![0.0f32; 8];
        ctx.par_chunks(&mut again, 1, |i, c| c.fill(i as f32));
        for (i, &v) in again.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
        assert_eq!(ctx.pool_handle().unwrap().live_workers(), 2);
    }

    /// With put-deferral, a region's scratch checkout count equals the
    /// worker count *exactly* — on the first region and on every one
    /// after — regardless of how ranges interleave in time.
    #[test]
    fn region_scratch_checkout_is_deterministic() {
        for threads in [2usize, 4] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads)
                .with_pool(WorkerPool::new(threads));
            let mut data = vec![0.0f32; 8];
            let region = |data: &mut [f32]| {
                ctx.par_chunks_with(
                    data,
                    1,
                    || ctx.take(32, 0.0),
                    |i, c, s| {
                        s[0] = i as f32;
                        c[0] = s[0];
                    },
                    |s| ctx.put(s),
                );
            };
            region(&mut data);
            assert_eq!(
                ctx.alloc_events(),
                threads,
                "threads={threads}: exactly one checkout per range"
            );
            for _ in 0..3 {
                region(&mut data);
            }
            assert_eq!(ctx.alloc_events(), threads, "threads={threads}: steady state");
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as f32);
            }
        }
    }

    #[test]
    fn pooled_workers_can_draw_scratch_concurrently() {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
        let mut data = vec![0.0f32; 32];
        ctx.par_chunks(&mut data, 1, |i, c| {
            let mut s = ctx.take(16, i as f32);
            s[0] += 1.0;
            c[0] = s[0];
            ctx.put(s);
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as f32 + 1.0);
        }
        assert!(ctx.arena_bytes() > 0, "scratch came back to the shared arena");
    }
}
