//! CPU-affinity pinning: a parsed core set plus a thread-pinning
//! primitive, so pool workers and coordinator replicas can stay resident
//! on their cores and keep their first-touched memory node-local (the
//! ZNNi / SLIDE observation: multi-core CPU throughput is won by
//! *placing* threads, not just spawning them).
//!
//! The crate builds offline with zero dependencies, so on Linux the pin
//! is a direct `sched_setaffinity` syscall (x86-64 and aarch64 inline
//! asm); everywhere else [`pin_current`] is a no-op that reports `false`.
//! Pinning is always best-effort: a sandbox that rejects the syscall
//! degrades to unpinned scheduling, never to an error.

use crate::error::{bail, Result};
use std::fmt;

/// A set of CPU core ids, parsed from the CLI's `--pin 0-3,8` syntax:
/// comma-separated core ids and inclusive ranges.
///
/// Core ids are kept sorted and deduplicated, so a set renders back in
/// canonical form and [`CoreSet::split`] distributes deterministically.
///
/// # Examples
///
/// ```
/// use swconv::exec::affinity::CoreSet;
///
/// let set = CoreSet::parse("0-3,8").unwrap();
/// assert_eq!(set.cores(), &[0, 1, 2, 3, 8]);
/// assert_eq!(set.to_string(), "0-3,8");
/// // Replica 0 of 2 gets the even half, replica 1 the odd half.
/// let halves = set.split(2);
/// assert_eq!(halves[0].cores(), &[0, 2, 8]);
/// assert_eq!(halves[1].cores(), &[1, 3]);
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CoreSet {
    cores: Vec<usize>,
}

/// Largest core id a [`CoreSet`] accepts. Bounds the affinity-mask
/// allocation; matches the kernel's default `CPU_SETSIZE`.
pub const MAX_CORE_ID: usize = 1023;

impl CoreSet {
    /// Parse `"0-3,8"`-style syntax: comma-separated core ids and
    /// inclusive `lo-hi` ranges. Rejects empty input, malformed numbers,
    /// inverted ranges and ids above [`MAX_CORE_ID`].
    pub fn parse(s: &str) -> Result<CoreSet> {
        let mut cores = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("empty element in core set '{s}'");
            }
            let (lo, hi) = match part.split_once('-') {
                None => {
                    let c = parse_core(part)?;
                    (c, c)
                }
                Some((a, b)) => (parse_core(a)?, parse_core(b)?),
            };
            if lo > hi {
                bail!("inverted core range '{part}'");
            }
            cores.extend(lo..=hi);
        }
        Ok(Self::from_cores(&cores))
    }

    /// Set from explicit core ids (sorted and deduplicated).
    pub fn from_cores(cores: &[usize]) -> CoreSet {
        let mut cores = cores.to_vec();
        cores.sort_unstable();
        cores.dedup();
        CoreSet { cores }
    }

    /// Cores `0..n` — "every hardware thread", the auto-pinning base set
    /// (`n` is normally [`super::available_threads`]).
    pub fn all(n: usize) -> CoreSet {
        CoreSet { cores: (0..n).collect() }
    }

    /// The core ids, ascending.
    pub fn cores(&self) -> &[usize] {
        &self.cores
    }

    /// Number of cores in the set.
    pub fn len(&self) -> usize {
        self.cores.len()
    }

    /// True when the set holds no cores.
    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// Whether `core` is in the set.
    pub fn contains(&self, core: usize) -> bool {
        self.cores.binary_search(&core).is_ok()
    }

    /// The `i`-th core, wrapping around the set — how a pool assigns its
    /// `w`-th worker a core when it has more workers than cores.
    ///
    /// # Panics
    /// If the set is empty.
    pub fn nth_wrapped(&self, i: usize) -> usize {
        self.cores[i % self.cores.len()]
    }

    /// Split into `parts` sub-sets by round-robin (core `j` of the
    /// ascending list goes to part `j % parts`): the per-replica core
    /// slices of a pinned serving tier. A part that would come up empty
    /// (more parts than cores) falls back to one wrapped core, so every
    /// replica always has somewhere to run.
    ///
    /// # Panics
    /// If `parts` is zero or the set is empty.
    pub fn split(&self, parts: usize) -> Vec<CoreSet> {
        assert!(parts > 0, "split needs at least one part");
        assert!(!self.is_empty(), "cannot split an empty core set");
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); parts];
        for (j, &c) in self.cores.iter().enumerate() {
            out[j % parts].push(c);
        }
        out.into_iter()
            .enumerate()
            .map(|(i, cores)| {
                if cores.is_empty() {
                    CoreSet { cores: vec![self.nth_wrapped(i)] }
                } else {
                    CoreSet { cores }
                }
            })
            .collect()
    }

    /// Cores present in both sets (ascending, like every `CoreSet`).
    pub fn intersect(&self, other: &CoreSet) -> CoreSet {
        CoreSet { cores: self.cores.iter().copied().filter(|&c| other.contains(c)).collect() }
    }

    /// Split into `parts` slices aligned to NUMA `nodes` boundaries.
    /// With `parts <= nodes` each slice is a union of *whole* nodes
    /// (node `j` goes to slice `j % parts`), so every core is used and
    /// no slice splits a node; with more parts than nodes, node `j` of
    /// `n` hosts replicas `j, j+n, j+2n, …`, sub-split within the node
    /// — either way a pinned replica's kernel threads, and the scratch
    /// pages they first-touch, never straddle a node they don't own
    /// outright. Falls back to plain round-robin [`CoreSet::split`]
    /// when fewer than two nodes intersect the set, or when the nodes
    /// don't cover every core in it (a topology-blind split at least
    /// uses all the cores).
    ///
    /// # Panics
    /// If `parts` is zero or the set is empty.
    pub fn split_by_nodes(&self, parts: usize, nodes: &[CoreSet]) -> Vec<CoreSet> {
        assert!(parts > 0, "split needs at least one part");
        assert!(!self.is_empty(), "cannot split an empty core set");
        let local: Vec<CoreSet> =
            nodes.iter().map(|n| self.intersect(n)).filter(|s| !s.is_empty()).collect();
        let covered: usize = local.iter().map(|s| s.len()).sum();
        if local.len() < 2 || covered < self.len() {
            return self.split(parts);
        }
        if parts <= local.len() {
            let mut out: Vec<Vec<usize>> = vec![Vec::new(); parts];
            for (j, node) in local.iter().enumerate() {
                out[j % parts].extend_from_slice(node.cores());
            }
            return out.into_iter().map(|cores| CoreSet::from_cores(&cores)).collect();
        }
        (0..parts)
            .map(|i| {
                let j = i % local.len();
                let hosted = (parts - j).div_ceil(local.len());
                local[j].split(hosted)[i / local.len()].clone()
            })
            .collect()
    }

    /// The affinity bitmask (`u64` words, bit `c % 64` of word `c / 64`)
    /// `sched_setaffinity` takes.
    fn mask_words(&self) -> Vec<u64> {
        let top = self.cores.last().copied().unwrap_or(0);
        let mut words = vec![0u64; top / 64 + 1];
        for &c in &self.cores {
            words[c / 64] |= 1u64 << (c % 64);
        }
        words
    }
}

/// The machine's NUMA node topology as one [`CoreSet`] per node, read
/// from `/sys/devices/system/node/node*/cpulist` (the kernel emits the
/// same `0-3,8` syntax [`CoreSet::parse`] accepts). Nodes come back
/// sorted by node id. Returns `None` when sysfs is absent (non-Linux,
/// sandboxes) or yields no parseable node — callers fall back to
/// topology-blind round-robin splitting.
pub fn numa_nodes() -> Option<Vec<CoreSet>> {
    numa_nodes_from("/sys/devices/system/node")
}

/// [`numa_nodes`] against an arbitrary root directory, so tests can
/// exercise the parse on a synthetic sysfs tree.
fn numa_nodes_from(root: &str) -> Option<Vec<CoreSet>> {
    let entries = std::fs::read_dir(root).ok()?;
    let mut nodes: Vec<(usize, CoreSet)> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let id: usize = match name.strip_prefix("node").and_then(|s| s.parse().ok()) {
            Some(id) => id,
            None => continue,
        };
        let cpulist = entry.path().join("cpulist");
        let text = match std::fs::read_to_string(&cpulist) {
            Ok(t) => t,
            Err(_) => continue,
        };
        if let Ok(set) = CoreSet::parse(text.trim()) {
            if !set.is_empty() {
                nodes.push((id, set));
            }
        }
    }
    if nodes.is_empty() {
        return None;
    }
    nodes.sort_by_key(|(id, _)| *id);
    Some(nodes.into_iter().map(|(_, set)| set).collect())
}

fn parse_core(s: &str) -> Result<usize> {
    let c: usize = match s.trim().parse() {
        Ok(c) => c,
        Err(_) => bail!("bad core id '{s}'"),
    };
    if c > MAX_CORE_ID {
        bail!("core id {c} above the supported maximum {MAX_CORE_ID}");
    }
    Ok(c)
}

impl fmt::Display for CoreSet {
    /// Canonical `--pin` syntax: ranges re-compressed (`0-3,8`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut i = 0;
        while i < self.cores.len() {
            let lo = self.cores[i];
            let mut hi = lo;
            while i + 1 < self.cores.len() && self.cores[i + 1] == hi + 1 {
                i += 1;
                hi = self.cores[i];
            }
            if !first {
                write!(f, ",")?;
            }
            first = false;
            if lo == hi {
                write!(f, "{lo}")?;
            } else {
                write!(f, "{lo}-{hi}")?;
            }
            i += 1;
        }
        if first {
            write!(f, "(empty)")?;
        }
        Ok(())
    }
}

/// Whether this build can actually pin threads (Linux on x86-64 or
/// aarch64). When `false`, [`pin_current`] is a documented no-op.
pub fn pinning_supported() -> bool {
    cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))
}

/// Pin the calling thread to the given cores. Returns whether the kernel
/// accepted the mask; `false` on unsupported platforms, an empty set, or
/// a rejected syscall (sandboxes) — callers treat that as "run unpinned",
/// never as an error.
pub fn pin_current(set: &CoreSet) -> bool {
    if set.is_empty() {
        return false;
    }
    let words = set.mask_words();
    sched_setaffinity_current(&words)
}

/// [`pin_current`] with a single-core set: how a pool worker takes
/// exclusive residence on its slice core.
pub fn pin_current_to_core(core: usize) -> bool {
    pin_current(&CoreSet::from_cores(&[core]))
}

/// `sched_setaffinity(0, size, mask)` for the calling thread (pid 0 =
/// "the calling thread" for this syscall). Direct syscall — the build is
/// dependency-free, so there is no libc to call through.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_current(mask: &[u64]) -> bool {
    const SYS_SCHED_SETAFFINITY: usize = 203;
    let ret: isize;
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") SYS_SCHED_SETAFFINITY as isize => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

/// `sched_setaffinity(0, size, mask)` for the calling thread (aarch64).
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_current(mask: &[u64]) -> bool {
    const SYS_SCHED_SETAFFINITY: usize = 122;
    let ret: isize;
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") SYS_SCHED_SETAFFINITY,
            inlateout("x0") 0isize => ret,
            in("x1") std::mem::size_of_val(mask),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret == 0
}

/// No-op fallback: pinning silently unsupported off Linux/x86-64/aarch64.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sched_setaffinity_current(_mask: &[u64]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_ids_and_ranges() {
        assert_eq!(CoreSet::parse("0").unwrap().cores(), &[0]);
        assert_eq!(CoreSet::parse("0-3,8").unwrap().cores(), &[0, 1, 2, 3, 8]);
        assert_eq!(CoreSet::parse(" 2 , 4-5 ").unwrap().cores(), &[2, 4, 5]);
        // Overlap and duplicates collapse.
        assert_eq!(CoreSet::parse("1-3,2,3-4").unwrap().cores(), &[1, 2, 3, 4]);
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", ",", "a", "3-", "-3", "5-2", "1,,2", "99999"] {
            assert!(CoreSet::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn display_is_canonical_and_roundtrips() {
        for s in ["0", "0-3", "0-3,8", "1,3,5", "0-1,4-6,9"] {
            let set = CoreSet::parse(s).unwrap();
            assert_eq!(set.to_string(), s);
            assert_eq!(CoreSet::parse(&set.to_string()).unwrap(), set);
        }
        assert_eq!(CoreSet::from_cores(&[]).to_string(), "(empty)");
    }

    #[test]
    fn split_round_robins_and_never_returns_empty_parts() {
        let set = CoreSet::parse("0-5").unwrap();
        let parts = set.split(2);
        assert_eq!(parts[0].cores(), &[0, 2, 4]);
        assert_eq!(parts[1].cores(), &[1, 3, 5]);
        // More parts than cores: the tail parts wrap instead of being
        // empty, so every replica gets a core.
        let set = CoreSet::parse("0-1").unwrap();
        let parts = set.split(3);
        assert_eq!(parts.len(), 3);
        for p in &parts {
            assert!(!p.is_empty());
        }
        assert_eq!(parts[2].cores(), &[0]);
    }

    #[test]
    fn mask_words_set_the_right_bits() {
        let set = CoreSet::parse("0,1,64").unwrap();
        assert_eq!(set.mask_words(), vec![0b11u64, 0b1u64]);
        assert!(set.contains(64));
        assert!(!set.contains(2));
        assert_eq!(set.nth_wrapped(5), set.cores()[5 % 3]);
    }

    #[test]
    fn intersect_keeps_common_cores() {
        let a = CoreSet::parse("0-5").unwrap();
        let b = CoreSet::parse("4-9").unwrap();
        assert_eq!(a.intersect(&b).cores(), &[4, 5]);
        assert!(a.intersect(&CoreSet::from_cores(&[])).is_empty());
    }

    #[test]
    fn split_by_nodes_keeps_slices_inside_one_node() {
        let base = CoreSet::parse("0-7").unwrap();
        let nodes = [CoreSet::parse("0-3").unwrap(), CoreSet::parse("4-7").unwrap()];
        // A sole replica keeps the whole machine (union of all nodes).
        assert_eq!(base.split_by_nodes(1, &nodes), vec![base.clone()]);
        // One replica per node: each slice IS a node.
        let two = base.split_by_nodes(2, &nodes);
        assert_eq!(two[0].cores(), &[0, 1, 2, 3]);
        assert_eq!(two[1].cores(), &[4, 5, 6, 7]);
        // Two replicas per node: sub-split within the node, never
        // straddling the boundary.
        let four = base.split_by_nodes(4, &nodes);
        assert_eq!(four.len(), 4);
        for (i, slice) in four.iter().enumerate() {
            let node = &nodes[i % 2];
            assert!(
                slice.cores().iter().all(|&c| node.contains(c)),
                "slice {i} ({slice}) straddles a node boundary"
            );
        }
        // Odd replica counts still cover: 3 parts over 2 nodes puts two
        // replicas on node 0 and one (whole-node) on node 1.
        let three = base.split_by_nodes(3, &nodes);
        assert_eq!(three[1].cores(), &[4, 5, 6, 7]);
        assert!(three[0].cores().iter().all(|&c| nodes[0].contains(c)));
        assert!(three[2].cores().iter().all(|&c| nodes[0].contains(c)));
    }

    #[test]
    fn split_by_nodes_falls_back_to_round_robin() {
        let base = CoreSet::parse("0-5").unwrap();
        // Single node (or none): topology adds nothing, plain split.
        assert_eq!(base.split_by_nodes(2, &[base.clone()]), base.split(2));
        assert_eq!(base.split_by_nodes(2, &[]), base.split(2));
        // Nodes that don't cover the whole set: fall back rather than
        // silently dropping the uncovered cores.
        let partial = [CoreSet::parse("0-1").unwrap(), CoreSet::parse("2-3").unwrap()];
        assert_eq!(base.split_by_nodes(2, &partial), base.split(2));
    }

    #[test]
    fn numa_nodes_parse_a_synthetic_sysfs_tree() {
        let root = std::env::temp_dir().join(format!("swconv_numa_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (node, cpulist) in [("node1", "8-15\n"), ("node0", "0-7\n")] {
            let dir = root.join(node);
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(dir.join("cpulist"), cpulist).unwrap();
        }
        // Distractors the parser must skip: non-node entries and a node
        // directory without a cpulist.
        std::fs::create_dir_all(root.join("possible")).unwrap();
        std::fs::create_dir_all(root.join("node9")).unwrap();
        let nodes = numa_nodes_from(root.to_str().unwrap()).expect("two nodes parse");
        assert_eq!(nodes.len(), 2, "node9 (no cpulist) and 'possible' are skipped");
        assert_eq!(nodes[0].cores(), (0..8).collect::<Vec<_>>().as_slice(), "sorted by id");
        assert_eq!(nodes[1].cores(), (8..16).collect::<Vec<_>>().as_slice());
        let _ = std::fs::remove_dir_all(&root);
        // A missing root is `None`, never an error.
        assert!(numa_nodes_from(root.to_str().unwrap()).is_none());
    }

    #[test]
    fn pin_current_is_best_effort() {
        // Pinning to every hardware thread is a no-op placement-wise, so
        // this only exercises the syscall path; a sandbox may reject it,
        // which must read as `false`, not a crash.
        let all = CoreSet::all(crate::exec::available_threads());
        let ok = pin_current(&all);
        if !pinning_supported() {
            assert!(!ok, "unsupported platforms must report false");
        }
        // An empty set is never "pinned".
        assert!(!pin_current(&CoreSet::from_cores(&[])));
    }
}
