//! Minimal error handling — the offline substitute for the `anyhow`
//! crate (the build environment has no registry access, so the crate is
//! kept dependency-free).
//!
//! Mirrors the subset of `anyhow` the codebase uses: a string-backed
//! [`Error`], the [`anyhow!`]/[`bail!`] macros, a [`Context`] extension
//! trait, and a [`Result`] alias defaulting the error type.

use std::fmt;

/// A string-backed error value.
///
/// Deliberately does **not** implement `std::error::Error`: that keeps
/// the blanket `From<E: std::error::Error>` conversion below coherent
/// (the same trick `anyhow::Error` relies on), so `?` works on any
/// standard error type.
pub struct Error(String);

impl Error {
    /// Build an error from a message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, `anyhow::Context`-style.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error(format!("{c}: {e}"))
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error(format!("{}: {e}", f()))
        })
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::error::Error::msg(format!($($t)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:?}"), "boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_wraps() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("loading manifest").unwrap_err();
        assert!(e.to_string().starts_with("loading manifest: "));
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e2.to_string(), "step 3: inner");
    }
}
