//! The *slide* primitive: shift lanes across a register pair.
//!
//! `slide::<J>(a, b)` produces the vector whose lane `i` is lane `i + J`
//! of the 2·LANES-lane concatenation `a ‖ b` — AVX-512's `valignd`
//! instruction. It is the building block of the Vector Slide convolution:
//! the window of the input starting at offset `J` is obtained from two
//! already-loaded registers with one shuffle, instead of re-reading memory
//! (GEMM/im2col) or performing a scalar gather (naïve convolution).
//!
//! Two forms are provided:
//! * [`slide`] — `J` is a const generic, so the lane mapping is known at
//!   compile time and LLVM emits a single `valignd`. The custom k=3/k=5
//!   kernels and the unrolled generic kernel use this form.
//! * [`slide_dyn`] — runtime `j`, dispatched through a match so each arm
//!   is still a const slide. The paper's "generic" kernel pays exactly
//!   this dispatch cost, which is one reason its custom kernels win.

use super::vector::{F32xL, LANES};

/// Compile-time slide: lane `i` of the result is lane `i + J` of `a ‖ b`.
///
/// `J` must be in `0..=LANES`; `slide::<0>` is `a`, `slide::<LANES>` is `b`.
#[inline(always)]
pub fn slide<const J: usize>(a: F32xL, b: F32xL) -> F32xL {
    const { assert!(J <= LANES) };
    let mut out = [0.0; LANES];
    for i in 0..LANES {
        out[i] = if i + J < LANES {
            a.0[i + J]
        } else {
            b.0[i + J - LANES]
        };
    }
    F32xL(out)
}

/// Runtime slide: dispatches to the const form. `j` must be `<= LANES`.
///
/// # Panics
/// If `j > LANES`.
#[inline(always)]
pub fn slide_dyn(a: F32xL, b: F32xL, j: usize) -> F32xL {
    // A 17-way match: every arm is a compile-time shuffle. This is the
    // "redundant shuffle" overhead the paper's custom kernels eliminate.
    match j {
        0 => slide::<0>(a, b),
        1 => slide::<1>(a, b),
        2 => slide::<2>(a, b),
        3 => slide::<3>(a, b),
        4 => slide::<4>(a, b),
        5 => slide::<5>(a, b),
        6 => slide::<6>(a, b),
        7 => slide::<7>(a, b),
        8 => slide::<8>(a, b),
        9 => slide::<9>(a, b),
        10 => slide::<10>(a, b),
        11 => slide::<11>(a, b),
        12 => slide::<12>(a, b),
        13 => slide::<13>(a, b),
        14 => slide::<14>(a, b),
        15 => slide::<15>(a, b),
        16 => slide::<16>(a, b),
        _ => panic!("slide_dyn: j={j} exceeds LANES={LANES}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (F32xL, F32xL) {
        let mut a = [0.0; LANES];
        let mut b = [0.0; LANES];
        for i in 0..LANES {
            a[i] = i as f32;
            b[i] = (LANES + i) as f32;
        }
        (F32xL(a), F32xL(b))
    }

    #[test]
    fn slide_zero_is_identity() {
        let (a, b) = pair();
        assert_eq!(slide::<0>(a, b), a);
        assert_eq!(slide::<LANES>(a, b), b);
    }

    #[test]
    fn slide_const_matches_concat() {
        let (a, b) = pair();
        let s = slide::<5>(a, b);
        for i in 0..LANES {
            assert_eq!(s.0[i], (i + 5) as f32);
        }
    }

    #[test]
    fn slide_dyn_matches_const_for_all_j() {
        let (a, b) = pair();
        for j in 0..=LANES {
            let s = slide_dyn(a, b, j);
            for i in 0..LANES {
                assert_eq!(s.0[i], (i + j) as f32, "j={j} lane={i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn slide_dyn_rejects_large_j() {
        let (a, b) = pair();
        let _ = slide_dyn(a, b, LANES + 1);
    }
}
