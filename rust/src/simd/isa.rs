//! Runtime ISA detection and dispatch levels.
//!
//! The portable [`crate::simd::F32xL`] kernels are correct everywhere but
//! leave throughput on the table when the build was not compiled with
//! `-C target-cpu=native`: without the target features enabled at compile
//! time, LLVM lowers the 16-lane loops to SSE2 (x86-64's baseline). The
//! explicit `std::arch` microkernels in [`crate::simd`]'s `x86`/`neon`
//! modules recover that throughput at *runtime*: this module detects once
//! (per process, [`std::sync::OnceLock`]) which instruction set the
//! machine actually has and exposes the result as an [`IsaLevel`], the
//! dispatch key threaded through
//! [`crate::kernels::rowconv::RowKernel::row_fn_at`], `ExecCtx`, and the
//! autotuner's profile buckets.
//!
//! Levels:
//! * [`IsaLevel::Scalar`] — the portable `F32xL` kernels; always
//!   available, always the correctness reference.
//! * [`IsaLevel::Avx2`] — x86-64 with AVX2 **and** FMA (`_mm256_*`,
//!   8 × f32 per register).
//! * [`IsaLevel::Avx512`] — x86-64 with AVX-512F (`_mm512_*`, 16 × f32).
//!   Only compiled when the toolchain has the stabilized `_mm512`
//!   intrinsics (Rust ≥ 1.89; see `build.rs` / the `swconv_avx512` cfg);
//!   on older compilers the level simply reports unavailable.
//! * [`IsaLevel::Neon`] — aarch64 (NEON is mandatory there, 4 × f32).
//!
//! Forcing a level: tests and benches force a level *per context*
//! (`ExecCtx::with_isa`) or per call ([`RowKernel::row_fn_at`]); the CLI's
//! `--isa` flag forces the *process-wide* default via [`IsaLevel::force`],
//! which [`IsaLevel::effective`] then reports instead of the detected
//! level. Forcing an unavailable level is rejected — dispatch can
//! therefore never hand out an intrinsic the machine cannot execute, and
//! every wrapper double-checks availability and falls back to the
//! portable kernel besides.
//!
//! [`RowKernel::row_fn_at`]: crate::kernels::rowconv::RowKernel::row_fn_at

use crate::error::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// An instruction-set level the row kernels can be dispatched at.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IsaLevel {
    /// Portable [`crate::simd::F32xL`] kernels — always available.
    Scalar,
    /// x86-64 AVX2 + FMA (`_mm256_*`, 8 f32 lanes).
    Avx2,
    /// x86-64 AVX-512F (`_mm512_*`, 16 f32 lanes).
    Avx512,
    /// aarch64 NEON (`vfmaq_f32` & co., 4 f32 lanes).
    Neon,
}

/// Process-wide forced level (CLI `--isa`): 0 = none, else discriminant+1.
static FORCED: AtomicU8 = AtomicU8::new(0);

impl IsaLevel {
    /// All levels, in report order (portable first, widest last).
    pub const ALL: [IsaLevel; 4] =
        [IsaLevel::Scalar, IsaLevel::Avx2, IsaLevel::Avx512, IsaLevel::Neon];

    /// Stable name used in reports, `profile.json` and the `--isa` flag.
    pub fn name(self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Avx512 => "avx512",
            IsaLevel::Neon => "neon",
        }
    }

    /// Parse a stable name (inverse of [`IsaLevel::name`]).
    pub fn parse(s: &str) -> Option<IsaLevel> {
        Self::ALL.into_iter().find(|l| l.name() == s)
    }

    /// f32 lanes per hardware register at this level. `Scalar` reports
    /// the portable model's [`crate::simd::LANES`] — `F32xL` *models* a
    /// 16-lane register even when LLVM lowers it narrower.
    pub fn lanes(self) -> usize {
        match self {
            IsaLevel::Scalar => crate::simd::LANES,
            IsaLevel::Avx2 => 8,
            IsaLevel::Avx512 => 16,
            IsaLevel::Neon => 4,
        }
    }

    /// Whether this machine (and this build) can execute kernels at this
    /// level. `Scalar` is always available.
    pub fn available(self) -> bool {
        match self {
            IsaLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            IsaLevel::Avx2 => {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(all(target_arch = "x86_64", swconv_avx512))]
            IsaLevel::Avx512 => IsaLevel::Avx2.available() && is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            IsaLevel::Neon => true, // NEON is mandatory on aarch64.
            _ => false,
        }
    }

    /// The best level this machine supports, detected once per process.
    pub fn detected() -> IsaLevel {
        static DETECTED: OnceLock<IsaLevel> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            [IsaLevel::Avx512, IsaLevel::Neon, IsaLevel::Avx2]
                .into_iter()
                .find(|l| l.available())
                .unwrap_or(IsaLevel::Scalar)
        })
    }

    /// Every available level, portable first — the grid the autotuner
    /// races and the parity/bench suites sweep.
    pub fn available_levels() -> Vec<IsaLevel> {
        Self::ALL.into_iter().filter(|l| l.available()).collect()
    }

    /// Force the process-wide default level (the CLI `--isa` knob).
    ///
    /// Rejects levels the machine cannot execute; forcing `Scalar` is
    /// always legal (that is the point of the knob: exercising the
    /// fallback path on capable hardware). Prefer `ExecCtx::with_isa`
    /// in tests — this global is for process entry points.
    pub fn force(level: IsaLevel) -> Result<()> {
        if !level.available() {
            bail!(
                "--isa {} not available on this machine (detected: {})",
                level.name(),
                IsaLevel::detected().name()
            );
        }
        let idx = Self::ALL.iter().position(|&l| l == level).unwrap() as u8 + 1;
        FORCED.store(idx, Ordering::Relaxed);
        Ok(())
    }

    /// The forced level, if [`IsaLevel::force`] has been called.
    pub fn forced() -> Option<IsaLevel> {
        match FORCED.load(Ordering::Relaxed) {
            0 => None,
            i => Some(Self::ALL[i as usize - 1]),
        }
    }

    /// The level new `ExecCtx`s dispatch at: the forced level if one is
    /// set, else the detected one.
    pub fn effective() -> IsaLevel {
        Self::forced().unwrap_or_else(Self::detected)
    }
}

impl std::fmt::Display for IsaLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for l in IsaLevel::ALL {
            assert_eq!(IsaLevel::parse(l.name()), Some(l));
        }
        assert_eq!(IsaLevel::parse("avx9000"), None);
    }

    #[test]
    fn scalar_is_always_available_and_detected_is_available() {
        assert!(IsaLevel::Scalar.available());
        assert!(IsaLevel::detected().available());
        assert!(IsaLevel::available_levels().contains(&IsaLevel::Scalar));
    }

    #[test]
    fn at_most_one_simd_arch_is_available() {
        // AVX and NEON live on different architectures; a machine never
        // reports both. (Guards the detection order in `detected`.)
        assert!(!(IsaLevel::Avx2.available() && IsaLevel::Neon.available()));
    }

    #[test]
    fn lanes_model() {
        assert_eq!(IsaLevel::Scalar.lanes(), crate::simd::LANES);
        assert_eq!(IsaLevel::Avx2.lanes(), 8);
        assert_eq!(IsaLevel::Avx512.lanes(), 16);
        assert_eq!(IsaLevel::Neon.lanes(), 4);
    }

    #[test]
    fn forcing_an_unavailable_level_is_rejected() {
        if let Some(&bad) = IsaLevel::ALL.iter().find(|l| !l.available()) {
            let err = IsaLevel::force(bad).unwrap_err();
            assert!(err.to_string().contains("not available"), "{err}");
            // The rejected force must not leak into the effective level.
            assert_ne!(IsaLevel::effective(), bad);
        }
    }
    // NOTE: the *successful* global force is exercised in its own
    // integration binary (`tests/isa_flag.rs`) — it mutates process
    // state, like the pooling kill-switch in `tests/pool_flag.rs`.
}
