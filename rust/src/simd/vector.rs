//! [`F32xL`]: the model of one hardware vector register (16 × f32).
//!
//! All operations are fixed-trip-count element-wise loops over a
//! 64-byte-aligned array. Built with `-C target-cpu=native` on an AVX-512
//! machine each op compiles to a single vector instruction (`vaddps`,
//! `vmulps`, `vfmadd...`, `vmaxps`), which is exactly the register model
//! the paper's kernels assume.

use std::ops::{Add, Mul, Sub};

/// Number of f32 lanes in one hardware vector (AVX-512 ZMM register).
///
/// The paper's Xeon 8272CL has 16 f32 lanes; the crossover phenomena it
/// reports (the generic/compound kernel handoff, zigzag at
/// compound/hardware misalignment) depend on this constant. The actual
/// filter-width limits each row-kernel family derives from `LANES` are
/// defined **once**, next to the kernels:
/// [`crate::kernels::rowconv::GENERIC_MAX_K`] (`LANES + 1`) and
/// [`crate::kernels::rowconv::COMPOUND_MAX_K`] (`7·LANES + 1`).
pub const LANES: usize = 16;

/// One hardware vector: 16 f32 lanes, 64-byte aligned (one ZMM register /
/// one cache line).
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(C, align(64))]
pub struct F32xL(pub [f32; LANES]);

impl F32xL {
    /// All lanes zero.
    #[inline(always)]
    pub fn zero() -> Self {
        F32xL([0.0; LANES])
    }

    /// Broadcast `v` to all lanes (`vbroadcastss`).
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        F32xL([v; LANES])
    }

    /// Unaligned load of `LANES` consecutive values starting at `src[0]`.
    ///
    /// # Panics
    /// If `src.len() < LANES`.
    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        let mut out = [0.0; LANES];
        out.copy_from_slice(&src[..LANES]);
        F32xL(out)
    }

    /// Load up to `LANES` values; missing lanes are filled with `fill`.
    ///
    /// Used for row tails where fewer than `LANES` outputs remain; `fill`
    /// is `0.0` for sums and `f32::NEG_INFINITY` for max-pooling.
    #[inline(always)]
    pub fn load_partial(src: &[f32], fill: f32) -> Self {
        let mut out = [fill; LANES];
        let n = src.len().min(LANES);
        out[..n].copy_from_slice(&src[..n]);
        F32xL(out)
    }

    /// Unaligned store of all lanes into `dst[..LANES]`.
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..LANES].copy_from_slice(&self.0);
    }

    /// Store the first `n` lanes only (row-tail store).
    #[inline(always)]
    pub fn store_partial(self, dst: &mut [f32], n: usize) {
        let n = n.min(LANES).min(dst.len());
        dst[..n].copy_from_slice(&self.0[..n]);
    }

    /// Fused multiply-add: `self * a + b` per lane (`vfmadd213ps`).
    #[inline(always)]
    pub fn mul_add(self, a: Self, b: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i].mul_add(a.0[i], b.0[i]);
        }
        F32xL(out)
    }

    /// Lane-wise maximum (`vmaxps`).
    #[inline(always)]
    pub fn max(self, other: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i].max(other.0[i]);
        }
        F32xL(out)
    }

    /// Lane-wise minimum (`vminps`).
    #[inline(always)]
    pub fn min(self, other: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i].min(other.0[i]);
        }
        F32xL(out)
    }

    /// Horizontal sum of all lanes.
    #[inline(always)]
    pub fn reduce_sum(self) -> f32 {
        // Pairwise tree reduction: better numerics than a serial fold and
        // compiles to log2(LANES) shuffles + adds.
        let mut acc = self.0;
        let mut width = LANES / 2;
        while width > 0 {
            for i in 0..width {
                acc[i] += acc[i + width];
            }
            width /= 2;
        }
        acc[0]
    }

    /// Horizontal max of all lanes.
    #[inline(always)]
    pub fn reduce_max(self) -> f32 {
        let mut acc = self.0;
        let mut width = LANES / 2;
        while width > 0 {
            for i in 0..width {
                acc[i] = acc[i].max(acc[i + width]);
            }
            width /= 2;
        }
        acc[0]
    }
}

impl Add for F32xL {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i] + rhs.0[i];
        }
        F32xL(out)
    }
}

impl Sub for F32xL {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i] - rhs.0[i];
        }
        F32xL(out)
    }
}

impl Mul for F32xL {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i] * rhs.0[i];
        }
        F32xL(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota() -> F32xL {
        let mut a = [0.0; LANES];
        for (i, v) in a.iter_mut().enumerate() {
            *v = i as f32;
        }
        F32xL(a)
    }

    #[test]
    fn splat_and_zero() {
        assert_eq!(F32xL::splat(2.5).0, [2.5; LANES]);
        assert_eq!(F32xL::zero().0, [0.0; LANES]);
    }

    #[test]
    fn load_store_roundtrip() {
        let src: Vec<f32> = (0..LANES + 4).map(|i| i as f32).collect();
        let v = F32xL::load(&src[2..]);
        assert_eq!(v.0[0], 2.0);
        assert_eq!(v.0[LANES - 1], (LANES + 1) as f32);
        let mut dst = vec![0.0; LANES];
        v.store(&mut dst);
        assert_eq!(&dst[..], &src[2..2 + LANES]);
    }

    #[test]
    fn load_partial_fills() {
        let src = [1.0, 2.0, 3.0];
        let v = F32xL::load_partial(&src, -9.0);
        assert_eq!(v.0[0..3], [1.0, 2.0, 3.0]);
        assert!(v.0[3..].iter().all(|&x| x == -9.0));
    }

    #[test]
    fn store_partial_clips() {
        let v = iota();
        let mut dst = [0.0f32; 4];
        v.store_partial(&mut dst, 10); // clipped to dst.len()
        assert_eq!(dst, [0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn arithmetic() {
        let a = iota();
        let b = F32xL::splat(2.0);
        assert_eq!((a + b).0[3], 5.0);
        assert_eq!((a - b).0[3], 1.0);
        assert_eq!((a * b).0[3], 6.0);
        assert_eq!(a.mul_add(b, b).0[3], 8.0); // 3*2+2
    }

    #[test]
    fn minmax() {
        let a = iota();
        let b = F32xL::splat(7.0);
        assert_eq!(a.max(b).0[3], 7.0);
        assert_eq!(a.max(b).0[12], 12.0);
        assert_eq!(a.min(b).0[3], 3.0);
        assert_eq!(a.min(b).0[12], 7.0);
    }

    #[test]
    fn reductions() {
        let a = iota();
        let expect: f32 = (0..LANES).map(|i| i as f32).sum();
        assert_eq!(a.reduce_sum(), expect);
        assert_eq!(a.reduce_max(), (LANES - 1) as f32);
    }
}
