//! Explicit AVX2 / AVX-512 microkernels (`std::arch`, x86-64 only).
//!
//! These are the register-tiled row-convolution inner loops the ISA
//! dispatcher ([`crate::kernels::rowconv::RowKernel::row_fn_at`]) hands
//! out on x86-64. Each kernel reproduces its portable counterpart's
//! arithmetic **exactly**:
//!
//! * f32 kernels fold taps in ascending `j` order with one fused
//!   multiply-add per tap per element — the same per-element operation
//!   chain as the portable [`crate::simd::F32xL::mul_add`] kernels, so
//!   results are bit-identical at any vector width or blocking.
//! * the int8 kernel accumulates exact i32 products (order-independent),
//! * the bf16 kernel uses a separate multiply then add (non-fused),
//!   matching the portable `row_conv_bf16` accumulation exactly.
//!
//! Row tails shorter than one vector run a scalar loop built on
//! `f32::mul_add` — still one rounding per tap, so the tail is
//! bit-identical too.
//!
//! Two shapes of f32 kernel:
//!
//! * **Custom k=3/k=5** — the paper's slide form: load one register pair
//!   per output vector, derive every tap window with an in-register
//!   shift. On AVX2 the shift is `_mm256_permutevar8x32_ps` on both
//!   registers + `_mm256_blendv_ps` (no single cross-lane `valign` exists
//!   pre-AVX-512); on AVX-512 it is one `_mm512_permutex2var_ps`
//!   (`vpermt2ps`), the native two-register lane extract.
//! * **Any-k streaming** — serves both the Generic and Compound families:
//!   per tap, one unaligned load at `src[x + j]` feeds several
//!   independent FMA accumulator chains. At 8/16 f32 per unaligned L1
//!   load there is no need for the portable code's register-pair slide
//!   economy, and the multi-chain unroll hides FMA latency. (The padding
//!   contract already guarantees `2·LANES` readable f32 past the last
//!   window, so full-width loads near the row end stay in bounds.)
//!
//! All functions are `unsafe` `#[target_feature]` items: the safe
//! wrappers in `kernels::rowconv` verify ISA availability (and assert the
//! padding contract) before calling in. AVX-512 kernels additionally sit
//! behind the `swconv_avx512` cfg — the `_mm512_*` intrinsics need
//! Rust ≥ 1.89 (probed by `build.rs`).

use core::arch::x86_64::*;

/// Scalar row tail for f32 kernels: `f32::mul_add` per tap in ascending
/// order — bit-identical to one lane of the portable partial block.
#[inline(always)]
fn f32_tail(src: &[f32], w: &[f32], dst: &mut [f32], from: usize, out_len: usize) {
    for i in from..out_len {
        let mut acc = dst[i];
        for (j, &wj) in w.iter().enumerate() {
            acc = wj.mul_add(src[i + j], acc);
        }
        dst[i] = acc;
    }
}

/// AVX2 slide across a register pair: lane `i` of the result is lane
/// `i + j` of `a ‖ b`, with `idx` = `splat(j) + iota (mod 8)` and
/// `take_b` the sign-bit mask of lanes with `i + j >= 8`. This is the
/// `_mm256_permutevar8x32_ps` form of the paper's slide primitive.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn slide8(a: __m256, b: __m256, idx: __m256i, take_b: __m256) -> __m256 {
    let pa = _mm256_permutevar8x32_ps(a, idx);
    let pb = _mm256_permutevar8x32_ps(b, idx);
    _mm256_blendv_ps(pa, pb, take_b)
}

/// Rotate-index and source-select constants for an AVX2 slide by `j`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn slide8_consts(j: i32) -> (__m256i, __m256) {
    let iota = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
    let idx = _mm256_and_si256(_mm256_add_epi32(iota, _mm256_set1_epi32(j)), _mm256_set1_epi32(7));
    let take_b = _mm256_castsi256_ps(_mm256_cmpgt_epi32(
        _mm256_add_epi32(iota, _mm256_set1_epi32(j)),
        _mm256_set1_epi32(7),
    ));
    (idx, take_b)
}

/// Custom k = 3 row kernel, AVX2 slide form.
///
/// # Safety
/// AVX2 + FMA must be available; `w.len() == 3`, `dst.len() >= out_len`,
/// and `src` padded per the f32 row contract
/// (`src.len() >= out_len + 1 + 2·LANES` readable f32).
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn row_conv_custom3_avx2(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let (w0, w1, w2) = (_mm256_set1_ps(w[0]), _mm256_set1_ps(w[1]), _mm256_set1_ps(w[2]));
    let (i1, m1) = slide8_consts(1);
    let (i2, m2) = slide8_consts(2);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 8 <= out_len {
        let a = _mm256_loadu_ps(sp.add(x));
        let b = _mm256_loadu_ps(sp.add(x + 8));
        let mut acc = _mm256_loadu_ps(dp.add(x));
        acc = _mm256_fmadd_ps(w0, a, acc);
        acc = _mm256_fmadd_ps(w1, slide8(a, b, i1, m1), acc);
        acc = _mm256_fmadd_ps(w2, slide8(a, b, i2, m2), acc);
        _mm256_storeu_ps(dp.add(x), acc);
        x += 8;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Custom k = 5 row kernel, AVX2 slide form.
///
/// # Safety
/// As [`row_conv_custom3_avx2`], with `w.len() == 5`.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn row_conv_custom5_avx2(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let w0 = _mm256_set1_ps(w[0]);
    let w1 = _mm256_set1_ps(w[1]);
    let w2 = _mm256_set1_ps(w[2]);
    let w3 = _mm256_set1_ps(w[3]);
    let w4 = _mm256_set1_ps(w[4]);
    let (i1, m1) = slide8_consts(1);
    let (i2, m2) = slide8_consts(2);
    let (i3, m3) = slide8_consts(3);
    let (i4, m4) = slide8_consts(4);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 8 <= out_len {
        let a = _mm256_loadu_ps(sp.add(x));
        let b = _mm256_loadu_ps(sp.add(x + 8));
        let mut acc = _mm256_loadu_ps(dp.add(x));
        acc = _mm256_fmadd_ps(w0, a, acc);
        acc = _mm256_fmadd_ps(w1, slide8(a, b, i1, m1), acc);
        acc = _mm256_fmadd_ps(w2, slide8(a, b, i2, m2), acc);
        acc = _mm256_fmadd_ps(w3, slide8(a, b, i3, m3), acc);
        acc = _mm256_fmadd_ps(w4, slide8(a, b, i4, m4), acc);
        _mm256_storeu_ps(dp.add(x), acc);
        x += 8;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Any-width f32 streaming row kernel (serves Generic *and* Compound):
/// per tap one unaligned load per accumulator chain, four independent
/// chains (32 outputs) per main iteration.
///
/// # Safety
/// AVX2 + FMA must be available; `w.len() >= 1`, `dst.len() >= out_len`,
/// `src` padded per the f32 row contract.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn row_conv_f32_avx2(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 32 <= out_len {
        let mut acc0 = _mm256_loadu_ps(dp.add(x));
        let mut acc1 = _mm256_loadu_ps(dp.add(x + 8));
        let mut acc2 = _mm256_loadu_ps(dp.add(x + 16));
        let mut acc3 = _mm256_loadu_ps(dp.add(x + 24));
        for j in 0..k {
            let wv = _mm256_set1_ps(*w.get_unchecked(j));
            let p = sp.add(x + j);
            acc0 = _mm256_fmadd_ps(wv, _mm256_loadu_ps(p), acc0);
            acc1 = _mm256_fmadd_ps(wv, _mm256_loadu_ps(p.add(8)), acc1);
            acc2 = _mm256_fmadd_ps(wv, _mm256_loadu_ps(p.add(16)), acc2);
            acc3 = _mm256_fmadd_ps(wv, _mm256_loadu_ps(p.add(24)), acc3);
        }
        _mm256_storeu_ps(dp.add(x), acc0);
        _mm256_storeu_ps(dp.add(x + 8), acc1);
        _mm256_storeu_ps(dp.add(x + 16), acc2);
        _mm256_storeu_ps(dp.add(x + 24), acc3);
        x += 32;
    }
    while x + 8 <= out_len {
        let mut acc = _mm256_loadu_ps(dp.add(x));
        for j in 0..k {
            let wv = _mm256_set1_ps(*w.get_unchecked(j));
            acc = _mm256_fmadd_ps(wv, _mm256_loadu_ps(sp.add(x + j)), acc);
        }
        _mm256_storeu_ps(dp.add(x), acc);
        x += 8;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Exact signed-int8 row kernel: taps are consumed in pairs via
/// interleave → sign-extend → `_mm256_madd_epi16` into i32 accumulators.
///
/// `_mm256_maddubs_epi16` (the obvious one-instruction widening
/// multiply) is **unsigned × signed** and therefore wrong for our signed
/// codes; the unpack + `madd_epi16` form is exact for the full i8 × i8
/// range (each pair sum |2·128·128| = 2¹⁵ fits the i32 lanes `pmaddwd`
/// produces).
///
/// # Safety
/// AVX2 must be available; `w.len() >= 1`, `dst.len() >= out_len`, and
/// `src` padded per the q8 row contract
/// (`src.len() >= out_len - 1 + (k - 1) + LANES + 1`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn row_conv_q8_avx2(src: &[i8], w: &[i8], dst: &mut [i32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 16 <= out_len {
        let mut acc_lo = _mm256_setzero_si256(); // outputs x .. x+8
        let mut acc_hi = _mm256_setzero_si256(); // outputs x+8 .. x+16
        let mut j = 0;
        while j + 2 <= k {
            let wj = *w.get_unchecked(j) as u16 as u32;
            let wj1 = *w.get_unchecked(j + 1) as u16 as u32;
            let wpair = _mm256_set1_epi32((wj | (wj1 << 16)) as i32);
            let va = _mm_loadu_si128(sp.add(x + j) as *const __m128i);
            let vb = _mm_loadu_si128(sp.add(x + j + 1) as *const __m128i);
            let lo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(va, vb));
            let hi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(va, vb));
            acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(lo, wpair));
            acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(hi, wpair));
            j += 2;
        }
        if j < k {
            // Odd filter width: final tap paired with weight 0.
            let wj = *w.get_unchecked(j) as u16 as u32;
            let wpair = _mm256_set1_epi32(wj as i32);
            let va = _mm_loadu_si128(sp.add(x + j) as *const __m128i);
            let zero = _mm_setzero_si128();
            let lo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(va, zero));
            let hi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(va, zero));
            acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(lo, wpair));
            acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(hi, wpair));
        }
        let d0 = _mm256_loadu_si256(dp.add(x) as *const __m256i);
        let d1 = _mm256_loadu_si256(dp.add(x + 8) as *const __m256i);
        _mm256_storeu_si256(dp.add(x) as *mut __m256i, _mm256_add_epi32(d0, acc_lo));
        _mm256_storeu_si256(dp.add(x + 8) as *mut __m256i, _mm256_add_epi32(d1, acc_hi));
        x += 16;
    }
    for i in x..out_len {
        let mut acc = 0i32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj as i32 * src[i + j] as i32;
        }
        dst[i] += acc;
    }
}

/// bf16 expand-multiply row kernel: each load widens 8 bf16 words to f32
/// with a 16-bit lane shift, then multiplies and adds **non-fused** —
/// matching the portable `row_conv_bf16` accumulation bit for bit.
///
/// `src` is the raw `u16` view of the `Bf16` row (`#[repr(transparent)]`).
///
/// # Safety
/// AVX2 must be available; `w.len() >= 1`, `dst.len() >= out_len`, and
/// `src` padded per the bf16 row contract
/// (`src.len() >= out_len - 1 + (k - 1) + LANES + 1`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn row_conv_bf16_avx2(src: &[u16], w: &[f32], dst: &mut [f32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 8 <= out_len {
        let mut acc = _mm256_setzero_ps();
        for j in 0..k {
            let wv = _mm256_set1_ps(*w.get_unchecked(j));
            let raw = _mm_loadu_si128(sp.add(x + j) as *const __m128i); // 8 × u16
            let s = _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(raw)));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, s));
        }
        let d = _mm256_loadu_ps(dp.add(x));
        _mm256_storeu_ps(dp.add(x), _mm256_add_ps(d, acc));
        x += 8;
    }
    for i in x..out_len {
        let mut acc = 0.0f32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * f32::from_bits((src[i + j] as u32) << 16);
        }
        dst[i] += acc;
    }
}

/// Six-chain AVX2 FMA micro-loop for the per-ISA roofline peak
/// ([`crate::harness::roofline`]). FLOPs = `iters · 6 chains · 8 lanes · 2`.
///
/// # Safety
/// AVX2 + FMA must be available.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn fma_peak_avx2(iters: usize) -> f32 {
    let a = _mm256_set1_ps(0.999_999_9);
    let b = _mm256_set1_ps(1.0e-7);
    let mut c0 = _mm256_set1_ps(0.1);
    let mut c1 = _mm256_set1_ps(0.2);
    let mut c2 = _mm256_set1_ps(0.3);
    let mut c3 = _mm256_set1_ps(0.4);
    let mut c4 = _mm256_set1_ps(0.5);
    let mut c5 = _mm256_set1_ps(0.6);
    for _ in 0..iters {
        c0 = _mm256_fmadd_ps(c0, a, b);
        c1 = _mm256_fmadd_ps(c1, a, b);
        c2 = _mm256_fmadd_ps(c2, a, b);
        c3 = _mm256_fmadd_ps(c3, a, b);
        c4 = _mm256_fmadd_ps(c4, a, b);
        c5 = _mm256_fmadd_ps(c5, a, b);
    }
    let sum = _mm256_add_ps(
        _mm256_add_ps(_mm256_add_ps(c0, c1), _mm256_add_ps(c2, c3)),
        _mm256_add_ps(c4, c5),
    );
    let mut out = [0.0f32; 8];
    _mm256_storeu_ps(out.as_mut_ptr(), sum);
    out.iter().sum()
}

// ---------------------------------------------------------------------
// AVX-512F kernels — compiled only when the toolchain has the stabilized
// `_mm512_*` intrinsics (Rust ≥ 1.89; `build.rs` probes and sets the
// `swconv_avx512` cfg). The f32 slide is the native two-register lane
// extract `_mm512_permutex2var_ps` (`vpermt2ps`), exactly the portable
// `slide::<J>` at hardware width.
// ---------------------------------------------------------------------

/// 0..15 lane indices for `vpermt2ps` slides.
#[cfg(swconv_avx512)]
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn iota16() -> __m512i {
    _mm512_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
}

/// Custom k = 3 row kernel, AVX-512 slide form.
///
/// # Safety
/// AVX-512F must be available; contract as [`row_conv_custom3_avx2`].
#[cfg(swconv_avx512)]
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn row_conv_custom3_avx512(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let (w0, w1, w2) = (_mm512_set1_ps(w[0]), _mm512_set1_ps(w[1]), _mm512_set1_ps(w[2]));
    let iota = iota16();
    let i1 = _mm512_add_epi32(iota, _mm512_set1_epi32(1));
    let i2 = _mm512_add_epi32(iota, _mm512_set1_epi32(2));
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 16 <= out_len {
        let a = _mm512_loadu_ps(sp.add(x));
        let b = _mm512_loadu_ps(sp.add(x + 16));
        let mut acc = _mm512_loadu_ps(dp.add(x));
        acc = _mm512_fmadd_ps(w0, a, acc);
        acc = _mm512_fmadd_ps(w1, _mm512_permutex2var_ps(a, i1, b), acc);
        acc = _mm512_fmadd_ps(w2, _mm512_permutex2var_ps(a, i2, b), acc);
        _mm512_storeu_ps(dp.add(x), acc);
        x += 16;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Custom k = 5 row kernel, AVX-512 slide form.
///
/// # Safety
/// AVX-512F must be available; contract as [`row_conv_custom5_avx2`].
#[cfg(swconv_avx512)]
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn row_conv_custom5_avx512(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let w0 = _mm512_set1_ps(w[0]);
    let w1 = _mm512_set1_ps(w[1]);
    let w2 = _mm512_set1_ps(w[2]);
    let w3 = _mm512_set1_ps(w[3]);
    let w4 = _mm512_set1_ps(w[4]);
    let iota = iota16();
    let i1 = _mm512_add_epi32(iota, _mm512_set1_epi32(1));
    let i2 = _mm512_add_epi32(iota, _mm512_set1_epi32(2));
    let i3 = _mm512_add_epi32(iota, _mm512_set1_epi32(3));
    let i4 = _mm512_add_epi32(iota, _mm512_set1_epi32(4));
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 16 <= out_len {
        let a = _mm512_loadu_ps(sp.add(x));
        let b = _mm512_loadu_ps(sp.add(x + 16));
        let mut acc = _mm512_loadu_ps(dp.add(x));
        acc = _mm512_fmadd_ps(w0, a, acc);
        acc = _mm512_fmadd_ps(w1, _mm512_permutex2var_ps(a, i1, b), acc);
        acc = _mm512_fmadd_ps(w2, _mm512_permutex2var_ps(a, i2, b), acc);
        acc = _mm512_fmadd_ps(w3, _mm512_permutex2var_ps(a, i3, b), acc);
        acc = _mm512_fmadd_ps(w4, _mm512_permutex2var_ps(a, i4, b), acc);
        _mm512_storeu_ps(dp.add(x), acc);
        x += 16;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Any-width f32 streaming row kernel at AVX-512 width (two independent
/// 16-lane chains, 32 outputs per main iteration).
///
/// # Safety
/// AVX-512F must be available; contract as [`row_conv_f32_avx2`].
#[cfg(swconv_avx512)]
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn row_conv_f32_avx512(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 32 <= out_len {
        let mut acc0 = _mm512_loadu_ps(dp.add(x));
        let mut acc1 = _mm512_loadu_ps(dp.add(x + 16));
        for j in 0..k {
            let wv = _mm512_set1_ps(*w.get_unchecked(j));
            let p = sp.add(x + j);
            acc0 = _mm512_fmadd_ps(wv, _mm512_loadu_ps(p), acc0);
            acc1 = _mm512_fmadd_ps(wv, _mm512_loadu_ps(p.add(16)), acc1);
        }
        _mm512_storeu_ps(dp.add(x), acc0);
        _mm512_storeu_ps(dp.add(x + 16), acc1);
        x += 32;
    }
    while x + 16 <= out_len {
        let mut acc = _mm512_loadu_ps(dp.add(x));
        for j in 0..k {
            let wv = _mm512_set1_ps(*w.get_unchecked(j));
            acc = _mm512_fmadd_ps(wv, _mm512_loadu_ps(sp.add(x + j)), acc);
        }
        _mm512_storeu_ps(dp.add(x), acc);
        x += 16;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Six-chain AVX-512 FMA micro-loop for the per-ISA roofline peak.
/// FLOPs = `iters · 6 chains · 16 lanes · 2`.
///
/// # Safety
/// AVX-512F must be available.
#[cfg(swconv_avx512)]
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn fma_peak_avx512(iters: usize) -> f32 {
    let a = _mm512_set1_ps(0.999_999_9);
    let b = _mm512_set1_ps(1.0e-7);
    let mut c0 = _mm512_set1_ps(0.1);
    let mut c1 = _mm512_set1_ps(0.2);
    let mut c2 = _mm512_set1_ps(0.3);
    let mut c3 = _mm512_set1_ps(0.4);
    let mut c4 = _mm512_set1_ps(0.5);
    let mut c5 = _mm512_set1_ps(0.6);
    for _ in 0..iters {
        c0 = _mm512_fmadd_ps(c0, a, b);
        c1 = _mm512_fmadd_ps(c1, a, b);
        c2 = _mm512_fmadd_ps(c2, a, b);
        c3 = _mm512_fmadd_ps(c3, a, b);
        c4 = _mm512_fmadd_ps(c4, a, b);
        c5 = _mm512_fmadd_ps(c5, a, b);
    }
    let sum = _mm512_add_ps(
        _mm512_add_ps(_mm512_add_ps(c0, c1), _mm512_add_ps(c2, c3)),
        _mm512_add_ps(c4, c5),
    );
    let mut out = [0.0f32; 16];
    _mm512_storeu_ps(out.as_mut_ptr(), sum);
    out.iter().sum()
}
