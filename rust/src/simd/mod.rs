//! The portable "hardware vector" and its slide (lane-shift) primitives.
//!
//! The paper's kernels are written against an abstract SIMD register with a
//! `slide` operation that shifts lanes across a register pair (AVX-512
//! `valignd`). We model that register as [`F32xL`]: a `#[repr(align(64))]`
//! array of [`LANES`] = 16 `f32` values whose element-wise operations are
//! written as fixed-trip-count loops — with `-C target-cpu=native` LLVM
//! compiles each into a single AVX-512 instruction (verified in
//! EXPERIMENTS.md §Perf).
//!
//! Submodules:
//! * [`vector`] — `F32xL` and its arithmetic.
//! * [`mod@slide`]  — compile-time (`slide::<J>`) and runtime (`slide_dyn`)
//!   lane shifts across a register pair; the core of the Vector Slide
//!   algorithm.
//! * [`compound`] — the *compound vector*: several hardware vectors treated
//!   as one long vector, for filter widths that do not fit a single
//!   register (paper §2, "kernels of larger width").
//! * [`isa`] — runtime ISA detection ([`IsaLevel`]): which explicit
//!   `std::arch` microkernel set (AVX-512F / AVX2+FMA / NEON) this
//!   machine can dispatch to, with the portable kernels as the always-
//!   correct scalar fallback.
//! * `x86` / `neon` (crate-internal, per-arch) — the explicit intrinsic
//!   row kernels themselves, handed out through
//!   [`crate::kernels::rowconv::RowKernel::row_fn_at`].

pub mod vector;
pub mod slide;
pub mod compound;
pub mod isa;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

pub use compound::CompoundF32;
pub use isa::IsaLevel;
pub use slide::{slide, slide_dyn};
pub use vector::{F32xL, LANES};
