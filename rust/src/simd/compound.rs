//! The *compound vector*: several hardware vectors treated as one long
//! vector (paper §2: "kernels of larger width do not fit into the hardware
//! vector and require a special version that operates on multiple hardware
//! vectors treating them as a single long compound vector").
//!
//! A [`CompoundF32<R>`] holds `R` consecutive registers covering
//! `R · LANES` input lanes; [`CompoundF32::window`] extracts the
//! `LANES`-wide window starting at any offset `j ≤ (R-1)·LANES` with one
//! register-pair slide. The cross-register index arithmetic (`j / LANES`,
//! `j % LANES`) is the source of the paper's zigzag: when the filter width
//! is misaligned with `LANES` the last register is mostly wasted slack.

use super::slide::slide_dyn;
use super::vector::{F32xL, LANES};

/// `R` hardware vectors treated as one `R * LANES`-lane compound vector.
#[derive(Clone, Copy, Debug)]
pub struct CompoundF32<const R: usize>(pub [F32xL; R]);

impl<const R: usize> CompoundF32<R> {
    /// Number of lanes in the compound vector.
    pub const COMPOUND_LANES: usize = R * LANES;

    /// Load `R * LANES` consecutive values from `src`.
    ///
    /// # Panics
    /// If `src.len() < R * LANES`.
    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        let mut regs = [F32xL::zero(); R];
        for (r, reg) in regs.iter_mut().enumerate() {
            *reg = F32xL::load(&src[r * LANES..]);
        }
        CompoundF32(regs)
    }

    /// Load with a partial tail: lanes past `src.len()` are filled with
    /// `fill`.
    #[inline(always)]
    pub fn load_partial(src: &[f32], fill: f32) -> Self {
        let mut regs = [F32xL::splat(fill); R];
        for (r, reg) in regs.iter_mut().enumerate() {
            let start = r * LANES;
            if start >= src.len() {
                break;
            }
            *reg = F32xL::load_partial(&src[start..], fill);
        }
        CompoundF32(regs)
    }

    /// The `LANES`-wide window starting at compound-lane `j`.
    ///
    /// Requires `j + LANES <= R * LANES`, i.e. `j <= (R-1) * LANES`.
    ///
    /// # Panics
    /// If the window would read past the last register.
    #[inline(always)]
    pub fn window(&self, j: usize) -> F32xL {
        let r = j / LANES;
        let off = j % LANES;
        if off == 0 {
            // Aligned window: a whole register, no shuffle at all. Filter
            // widths aligned to LANES hit this fast path — the *dips* of
            // the paper's zigzag.
            self.0[r]
        } else {
            assert!(
                r + 1 < R,
                "compound window j={j} spills past R={R} registers"
            );
            slide_dyn(self.0[r], self.0[r + 1], off)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32).collect()
    }

    #[test]
    fn load_covers_all_registers() {
        let s = src(4 * LANES);
        let c = CompoundF32::<4>::load(&s);
        for r in 0..4 {
            for i in 0..LANES {
                assert_eq!(c.0[r].0[i], (r * LANES + i) as f32);
            }
        }
    }

    #[test]
    fn window_matches_concat_all_offsets() {
        let s = src(3 * LANES);
        let c = CompoundF32::<3>::load(&s);
        for j in 0..=2 * LANES {
            let w = c.window(j);
            for i in 0..LANES {
                assert_eq!(w.0[i], (j + i) as f32, "j={j} lane={i}");
            }
        }
    }

    #[test]
    fn window_aligned_is_register_copy() {
        let s = src(2 * LANES);
        let c = CompoundF32::<2>::load(&s);
        assert_eq!(c.window(LANES), c.0[1]);
    }

    #[test]
    #[should_panic(expected = "spills")]
    fn window_past_end_panics() {
        let s = src(2 * LANES);
        let c = CompoundF32::<2>::load(&s);
        let _ = c.window(LANES + 1); // needs register 2, doesn't exist
    }

    #[test]
    fn load_partial_fills_tail() {
        let s = src(LANES + 3);
        let c = CompoundF32::<2>::load_partial(&s, 0.0);
        assert_eq!(c.0[1].0[2], (LANES + 2) as f32);
        assert_eq!(c.0[1].0[3], 0.0);
        assert_eq!(c.0[1].0[LANES - 1], 0.0);
    }
}
