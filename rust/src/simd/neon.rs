//! Explicit NEON microkernels (`std::arch`, aarch64 only).
//!
//! The aarch64 members of the microkernel family handed out by
//! [`crate::kernels::rowconv::RowKernel::row_fn_at`]. Arithmetic parity
//! with the portable kernels follows the same rules as the x86 module:
//! f32 kernels are ascending-tap fused-FMA chains (`vfmaq_f32` rounds
//! once, like `f32::mul_add`), the int8 kernel is exact i32
//! accumulation, the bf16 kernel is non-fused multiply-then-add. Scalar
//! row tails use `f32::mul_add`, so every element — vector body or tail
//! — is bit-identical to the portable path.
//!
//! * The custom k=3/k=5 kernels use the native register-pair lane
//!   extract `vextq_f32` — aarch64's `EXT`, exactly the paper's slide
//!   primitive at 4-lane width.
//! * The any-k streaming kernel (serving Generic and Compound) issues
//!   one unaligned `vld1q_f32` per tap per chain, four chains deep.
//! * The int8 kernel widens with `vmovl_s8` and multiply-accumulates
//!   with `vmlal_s16` (`SMLAL`), which widens i16 products to i32 before
//!   adding — exact for the full i8 range. (`sdot` would be faster still
//!   but needs the optional `dotprod` feature and computes 4-tap groups,
//!   which does not fit the per-tap row layout; `SMLAL` is baseline
//!   NEON.)
//! * The bf16 kernel widens `u16 → u32` (`vmovl_u16`) and shifts into
//!   f32 bit position (`vshlq_n_u32::<16>`).
//!
//! NEON is mandatory on aarch64, so unlike AVX these kernels are always
//! available once the target is aarch64; the dispatch wrappers still
//! verify [`crate::simd::IsaLevel::available`] before calling in.

use core::arch::aarch64::*;

/// Scalar row tail for f32 kernels: `f32::mul_add` per tap in ascending
/// order — bit-identical to one lane of the portable partial block.
#[inline(always)]
fn f32_tail(src: &[f32], w: &[f32], dst: &mut [f32], from: usize, out_len: usize) {
    for i in from..out_len {
        let mut acc = dst[i];
        for (j, &wj) in w.iter().enumerate() {
            acc = wj.mul_add(src[i + j], acc);
        }
        dst[i] = acc;
    }
}

/// Custom k = 3 row kernel, `vextq_f32` slide form.
///
/// # Safety
/// NEON must be available; `w.len() == 3`, `dst.len() >= out_len`, and
/// `src` padded per the f32 row contract
/// (`src.len() >= out_len + 1 + 2·LANES` readable f32).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_conv_custom3_neon(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let (w0, w1, w2) = (vdupq_n_f32(w[0]), vdupq_n_f32(w[1]), vdupq_n_f32(w[2]));
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 4 <= out_len {
        let a = vld1q_f32(sp.add(x));
        let b = vld1q_f32(sp.add(x + 4));
        let mut acc = vld1q_f32(dp.add(x));
        acc = vfmaq_f32(acc, w0, a);
        acc = vfmaq_f32(acc, w1, vextq_f32::<1>(a, b));
        acc = vfmaq_f32(acc, w2, vextq_f32::<2>(a, b));
        vst1q_f32(dp.add(x), acc);
        x += 4;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Custom k = 5 row kernel, `vextq_f32` slide form. Tap 4 slides a full
/// register, so the window is simply the second register of the pair at
/// the next offset.
///
/// # Safety
/// As [`row_conv_custom3_neon`], with `w.len() == 5`.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_conv_custom5_neon(
    src: &[f32],
    w: &[f32],
    dst: &mut [f32],
    out_len: usize,
) {
    let w0 = vdupq_n_f32(w[0]);
    let w1 = vdupq_n_f32(w[1]);
    let w2 = vdupq_n_f32(w[2]);
    let w3 = vdupq_n_f32(w[3]);
    let w4 = vdupq_n_f32(w[4]);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut x = 0;
    while x + 4 <= out_len {
        let a = vld1q_f32(sp.add(x));
        let b = vld1q_f32(sp.add(x + 4));
        let mut acc = vld1q_f32(dp.add(x));
        acc = vfmaq_f32(acc, w0, a);
        acc = vfmaq_f32(acc, w1, vextq_f32::<1>(a, b));
        acc = vfmaq_f32(acc, w2, vextq_f32::<2>(a, b));
        acc = vfmaq_f32(acc, w3, vextq_f32::<3>(a, b));
        acc = vfmaq_f32(acc, w4, b);
        vst1q_f32(dp.add(x), acc);
        x += 4;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Any-width f32 streaming row kernel (serves Generic *and* Compound):
/// four independent FMA chains, 16 outputs per main iteration.
///
/// # Safety
/// NEON must be available; `w.len() >= 1`, `dst.len() >= out_len`, `src`
/// padded per the f32 row contract.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_conv_f32_neon(src: &[f32], w: &[f32], dst: &mut [f32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 16 <= out_len {
        let mut acc0 = vld1q_f32(dp.add(x));
        let mut acc1 = vld1q_f32(dp.add(x + 4));
        let mut acc2 = vld1q_f32(dp.add(x + 8));
        let mut acc3 = vld1q_f32(dp.add(x + 12));
        for j in 0..k {
            let wv = vdupq_n_f32(*w.get_unchecked(j));
            let p = sp.add(x + j);
            acc0 = vfmaq_f32(acc0, wv, vld1q_f32(p));
            acc1 = vfmaq_f32(acc1, wv, vld1q_f32(p.add(4)));
            acc2 = vfmaq_f32(acc2, wv, vld1q_f32(p.add(8)));
            acc3 = vfmaq_f32(acc3, wv, vld1q_f32(p.add(12)));
        }
        vst1q_f32(dp.add(x), acc0);
        vst1q_f32(dp.add(x + 4), acc1);
        vst1q_f32(dp.add(x + 8), acc2);
        vst1q_f32(dp.add(x + 12), acc3);
        x += 16;
    }
    while x + 4 <= out_len {
        let mut acc = vld1q_f32(dp.add(x));
        for j in 0..k {
            let wv = vdupq_n_f32(*w.get_unchecked(j));
            acc = vfmaq_f32(acc, wv, vld1q_f32(sp.add(x + j)));
        }
        vst1q_f32(dp.add(x), acc);
        x += 4;
    }
    f32_tail(src, w, dst, x, out_len);
}

/// Exact signed-int8 row kernel: widen with `vmovl_s8`, multiply-
/// accumulate with `vmlal_s16` (widens products to i32 before adding —
/// exact for the full i8 × i8 range).
///
/// # Safety
/// NEON must be available; `w.len() >= 1`, `dst.len() >= out_len`, and
/// `src` padded per the q8 row contract
/// (`src.len() >= out_len - 1 + (k - 1) + LANES + 1`).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_conv_q8_neon(src: &[i8], w: &[i8], dst: &mut [i32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 8 <= out_len {
        let mut acc0 = vdupq_n_s32(0); // outputs x .. x+4
        let mut acc1 = vdupq_n_s32(0); // outputs x+4 .. x+8
        for j in 0..k {
            let wv = vdupq_n_s16(*w.get_unchecked(j) as i16);
            let s16 = vmovl_s8(vld1_s8(sp.add(x + j)));
            acc0 = vmlal_s16(acc0, vget_low_s16(s16), vget_low_s16(wv));
            acc1 = vmlal_s16(acc1, vget_high_s16(s16), vget_high_s16(wv));
        }
        let d0 = vld1q_s32(dp.add(x));
        let d1 = vld1q_s32(dp.add(x + 4));
        vst1q_s32(dp.add(x), vaddq_s32(d0, acc0));
        vst1q_s32(dp.add(x + 4), vaddq_s32(d1, acc1));
        x += 8;
    }
    for i in x..out_len {
        let mut acc = 0i32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj as i32 * src[i + j] as i32;
        }
        dst[i] += acc;
    }
}

/// bf16 expand-multiply row kernel: widen `u16 → u32`, shift into f32
/// bit position, then multiply and add **non-fused** — matching the
/// portable `row_conv_bf16` accumulation bit for bit.
///
/// `src` is the raw `u16` view of the `Bf16` row (`#[repr(transparent)]`).
///
/// # Safety
/// NEON must be available; `w.len() >= 1`, `dst.len() >= out_len`, and
/// `src` padded per the bf16 row contract
/// (`src.len() >= out_len - 1 + (k - 1) + LANES + 1`).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_conv_bf16_neon(src: &[u16], w: &[f32], dst: &mut [f32], out_len: usize) {
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let k = w.len();
    let mut x = 0;
    while x + 4 <= out_len {
        let mut acc = vdupq_n_f32(0.0);
        for j in 0..k {
            let wv = vdupq_n_f32(*w.get_unchecked(j));
            let wide = vshlq_n_u32::<16>(vmovl_u16(vld1_u16(sp.add(x + j))));
            let s = vreinterpretq_f32_u32(wide);
            acc = vaddq_f32(acc, vmulq_f32(wv, s));
        }
        let d = vld1q_f32(dp.add(x));
        vst1q_f32(dp.add(x), vaddq_f32(d, acc));
        x += 4;
    }
    for i in x..out_len {
        let mut acc = 0.0f32;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * f32::from_bits((src[i + j] as u32) << 16);
        }
        dst[i] += acc;
    }
}

/// Six-chain NEON FMA micro-loop for the per-ISA roofline peak.
/// FLOPs = `iters · 6 chains · 4 lanes · 2`.
///
/// # Safety
/// NEON must be available.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn fma_peak_neon(iters: usize) -> f32 {
    let a = vdupq_n_f32(0.999_999_9);
    let b = vdupq_n_f32(1.0e-7);
    let mut c0 = vdupq_n_f32(0.1);
    let mut c1 = vdupq_n_f32(0.2);
    let mut c2 = vdupq_n_f32(0.3);
    let mut c3 = vdupq_n_f32(0.4);
    let mut c4 = vdupq_n_f32(0.5);
    let mut c5 = vdupq_n_f32(0.6);
    for _ in 0..iters {
        // c = c·a + b, the dependency carried through the multiplicand.
        c0 = vfmaq_f32(b, c0, a);
        c1 = vfmaq_f32(b, c1, a);
        c2 = vfmaq_f32(b, c2, a);
        c3 = vfmaq_f32(b, c3, a);
        c4 = vfmaq_f32(b, c4, a);
        c5 = vfmaq_f32(b, c5, a);
    }
    let sum = vaddq_f32(
        vaddq_f32(vaddq_f32(c0, c1), vaddq_f32(c2, c3)),
        vaddq_f32(c4, c5),
    );
    vaddvq_f32(sum)
}
