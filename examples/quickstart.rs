//! Quickstart: one 2-D convolution through every algorithm, verified
//! equal, plus the pooling primitives.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! From here, the CLI drives the full stack (see README.md):
//!
//! ```bash
//! cargo run --release -- bench-fig1 --threads 0      # paper Fig. 1, all cores
//! cargo run --release -- autotune                    # cache this machine's
//!                                                    #   dispatch crossovers
//! cargo run --release -- serve --replicas 2 --threads 2 --trim-mb 64 \
//!     --profile target/autotune/profile.json         # tuned, sharded serving
//! ```
//!
//! Every `--threads N` (0 = all hardware threads; default 1 = the
//! paper's single-core setup) is bit-deterministic; `serve --replicas`
//! shards batches across N worker replicas per backend and `--trim-mb`
//! caps each replica's retained scratch arena between batches.

use swconv::exec::ExecCtx;
use swconv::harness::{bench, machine_peaks};
use swconv::kernels::{
    avg_pool2d, conv2d, conv2d_ctx, max_pool2d, Conv2dParams, ConvAlgo, PoolParams,
};
use swconv::tensor::Tensor;

fn main() {
    // A small "edge camera frame": 3x64x64, 5x5 filter bank, same padding.
    let x = Tensor::randn(&[1, 3, 64, 64], 42);
    let w = Tensor::randn(&[8, 3, 5, 5], 7);
    let bias = vec![0.1f32; 8];
    let p = Conv2dParams::same(5);

    println!("input  {:?}", x.dims());
    println!("filter {:?} (same padding, stride 1)\n", w.dims());

    // Run every algorithm on identical data; all must agree.
    let reference = conv2d(&x, &w, Some(&bias), &p, ConvAlgo::Direct);
    println!("{:<18} {:>10}  {:>9}  {}", "algo", "median", "GFLOP/s", "max|diff| vs direct");
    let flops = 2 * 8 * 64 * 64 * 3 * 25;
    for algo in ConvAlgo::ALL {
        // One ctx per algorithm: the timed loop reuses arena scratch.
        let ctx = ExecCtx::new(algo);
        let stats = bench(|| conv2d_ctx(&x, &w, Some(&bias), &p, &ctx));
        let y = conv2d_ctx(&x, &w, Some(&bias), &p, &ctx);
        println!(
            "{:<18} {:>10.3?}  {:>9.2}  {:.2e}",
            algo.name(),
            stats.median,
            stats.gflops(flops),
            y.max_abs_diff(&reference)
        );
        assert!(y.allclose(&reference, 1e-3), "{algo:?} disagrees!");
    }

    // Pooling is a sliding window sum too (paper abstract).
    let mp = max_pool2d(&x, &PoolParams::square(2));
    let ap = avg_pool2d(&x, &PoolParams::square(2));
    println!("\nmax_pool2d 2x2 -> {:?}, avg_pool2d 2x2 -> {:?}", mp.dims(), ap.dims());

    let peaks = machine_peaks();
    println!(
        "\nmachine: {:.1} GFLOP/s peak, {:.1} GB/s bandwidth (ridge {:.1} FLOP/B)",
        peaks.gflops,
        peaks.bandwidth_gbs,
        peaks.ridge()
    );
    println!("quickstart OK");
}
