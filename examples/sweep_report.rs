//! Regenerate the paper's Fig. 1 and Fig. 2 as text tables + CSVs in one
//! shot (a lighter-weight alternative to the `swconv bench-fig1/2` CLI,
//! using a reduced grid so it finishes in ~a minute).
//!
//! ```bash
//! cargo run --release --example sweep_report
//! ```

use swconv::harness::report::{f3, Table};
use swconv::harness::{fig1_speedup_sweep, fig2_throughput_sweep, machine_peaks, ConvCase};

fn main() {
    let ks: Vec<usize> = vec![2, 3, 4, 5, 7, 9, 11, 13, 15, 17, 18, 21, 25, 31, 33];
    let make = |k| ConvCase::square(4, 64, k);

    let peaks = machine_peaks();
    println!(
        "machine: {:.1} GFLOP/s peak, {:.1} GB/s, ridge {:.1} FLOP/B\n",
        peaks.gflops,
        peaks.bandwidth_gbs,
        peaks.ridge()
    );

    let rows = fig1_speedup_sweep(&ks, 1, make);
    let mut t1 = Table::new(
        "Fig 1 — 2-D sliding convolution speedup over GEMM (c=4, 64x64)",
        &["k", "kernel", "speedup"],
    );
    for r in &rows {
        t1.row(vec![r.k.to_string(), r.kernel_used.into(), f3(r.speedup)]);
    }
    println!("{}", t1.render());
    t1.write_csv("target/reports/fig1_example.csv").expect("csv");

    let rows = fig2_throughput_sweep(&ks, 1, make);
    let mut t2 = Table::new(
        "Fig 2 — throughput GFLOP/s vs roofline (c=4, 64x64)",
        &["k", "sliding", "gemm", "roof(sliding)", "peak"],
    );
    for r in &rows {
        t2.row(vec![
            r.k.to_string(),
            f3(r.sliding_gflops),
            f3(r.gemm_gflops),
            f3(r.sliding_roof),
            f3(r.peak),
        ]);
    }
    println!("{}", t2.render());
    t2.write_csv("target/reports/fig2_example.csv").expect("csv");
    println!("CSVs written to target/reports/");
}
