//! Edge-audio example: a 1-D streaming feature pipeline (keyword-spotting
//! front-end) built from the Sliding Window primitives — the low-power
//! device scenario the paper's introduction motivates.
//!
//! Pipeline per frame: band-pass filterbank (conv1d) → rectify →
//! energy smoothing (sliding window sum) → decimation — then a simple
//! energy detector. Runs the filterbank with both the sliding and direct
//! kernels and reports the speedup.
//!
//! ```bash
//! cargo run --release --example edge_audio
//! ```

use swconv::exec::ExecCtx;
use swconv::harness::bench;
use swconv::kernels::sliding1d::sliding_sum;
use swconv::kernels::{conv1d, conv1d_ctx, Conv1dParams, ConvAlgo};
use swconv::tensor::{Tensor, XorShiftRng};

const SAMPLE_RATE: usize = 16_000;
const FRAME: usize = 4096;
const N_BANDS: usize = 8;
const TAPS: usize = 33; // FIR length — compound-kernel regime

/// Windowed-sinc band-pass FIR bank: `N_BANDS` filters of `TAPS` taps.
fn filterbank() -> Tensor {
    let mut w = Tensor::zeros(&[N_BANDS, 1, TAPS]);
    for b in 0..N_BANDS {
        let f_lo = 200.0 + 800.0 * b as f32;
        let f_hi = f_lo + 700.0;
        for t in 0..TAPS {
            let n = t as f32 - (TAPS as f32 - 1.0) / 2.0;
            let sinc = |f: f32| {
                let x = 2.0 * std::f32::consts::PI * f / SAMPLE_RATE as f32;
                if n.abs() < 1e-6 {
                    2.0 * f / SAMPLE_RATE as f32
                } else {
                    (x * n).sin() / (std::f32::consts::PI * n)
                }
            };
            // Band-pass = hi-lowpass minus lo-lowpass, Hamming windowed.
            let win = 0.54
                - 0.46
                    * (2.0 * std::f32::consts::PI * t as f32 / (TAPS as f32 - 1.0)).cos();
            let idx = (b * TAPS + t) as usize;
            w.as_mut_slice()[idx] = (sinc(f_hi) - sinc(f_lo)) * win;
        }
    }
    w
}

/// Synthetic utterance: two tone bursts + noise.
fn synth_frame(seed: u64) -> Tensor {
    let mut rng = XorShiftRng::new(seed);
    let mut x = vec![0.0f32; FRAME];
    for (i, v) in x.iter_mut().enumerate() {
        let t = i as f32 / SAMPLE_RATE as f32;
        let tone = |hz: f32| (2.0 * std::f32::consts::PI * hz * t).sin();
        let burst1 = if (0.05..0.12).contains(&t) { tone(700.0) } else { 0.0 };
        let burst2 = if (0.15..0.22).contains(&t) { tone(2600.0) } else { 0.0 };
        *v = 0.8 * burst1 + 0.7 * burst2 + 0.05 * rng.gauss();
    }
    Tensor::from_vec(x, &[1, FRAME])
}

fn main() {
    let w = filterbank();
    let frame = synth_frame(1);
    let p = Conv1dParams { stride: 1, pad: TAPS / 2 };

    // Correctness: sliding == direct on the filterbank.
    let y_slide = conv1d(&frame, &w, None, &p, ConvAlgo::Sliding);
    let y_direct = conv1d(&frame, &w, None, &p, ConvAlgo::Direct);
    let d = y_slide.max_abs_diff(&y_direct);
    println!("filterbank: {N_BANDS} bands x {TAPS} taps over {FRAME} samples");
    println!("sliding vs direct: max|diff| = {d:.2e}");
    assert!(d < 1e-3);

    // Throughput: the edge device budget question. One ctx per
    // algorithm so the timed loop reuses arena scratch across frames.
    let sliding = ExecCtx::new(ConvAlgo::Sliding);
    let direct = ExecCtx::new(ConvAlgo::Direct);
    let gemm = ExecCtx::new(ConvAlgo::Im2colGemm);
    let s_slide = bench(|| conv1d_ctx(&frame, &w, None, &p, &sliding));
    let s_direct = bench(|| conv1d_ctx(&frame, &w, None, &p, &direct));
    let s_gemm = bench(|| conv1d_ctx(&frame, &w, None, &p, &gemm));
    let rt = |t: std::time::Duration| {
        FRAME as f64 / SAMPLE_RATE as f64 / t.as_secs_f64()
    };
    println!("\nkernel timings (one {FRAME}-sample frame):");
    println!("  sliding : {:>10.3?}  ({:.0}x realtime)", s_slide.median, rt(s_slide.median));
    println!("  gemm    : {:>10.3?}  ({:.0}x realtime)", s_gemm.median, rt(s_gemm.median));
    println!("  direct  : {:>10.3?}  ({:.0}x realtime)", s_direct.median, rt(s_direct.median));
    println!(
        "  speedup sliding/gemm = {:.2}x, sliding/direct = {:.2}x",
        s_gemm.median.as_secs_f64() / s_slide.median.as_secs_f64(),
        s_direct.median.as_secs_f64() / s_slide.median.as_secs_f64()
    );

    // Energy envelope per band: rectify → sliding window sum (log-step
    // kernel) → decimate; detect which bands fire.
    println!("\nband energies (sliding-window-sum envelope, top value per band):");
    let lo = y_slide.dim(1);
    const WIN: usize = 16;
    for b in 0..N_BANDS {
        let band = &y_slide.as_slice()[b * lo..(b + 1) * lo];
        let rect: Vec<f32> = band.iter().map(|v| v * v).collect();
        let env = sliding_sum(&rect, WIN);
        let peak = env.iter().fold(0.0f32, |m, &v| m.max(v)) / WIN as f32;
        let bar = "#".repeat((peak.sqrt() * 60.0).min(60.0) as usize);
        println!("  band {b} ({:>4.0} Hz): {peak:>8.4}  {bar}", 200.0 + 800.0 * b as f32 + 350.0);
    }
    println!("\nedge_audio OK");
}
