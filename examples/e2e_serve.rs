//! END-TO-END DRIVER (the mandated validation example).
//!
//! Serves batched CNN inference through the full stack and proves all
//! layers compose:
//!
//! 1. L3 coordinator: router + dynamic batcher + metrics, three backends:
//!    * `sliding` — Rust Sliding Window kernels (the paper's technique)
//!    * `gemm`    — Rust im2col+GEMM kernels (the MlasConv baseline)
//!    * `pjrt`    — the AOT JAX/Pallas artifact (L1+L2) executed via PJRT
//! 2. A synthetic digit workload (deterministic) of N requests.
//! 3. Reports latency/throughput per backend and cross-checks numerics.
//!
//! The PJRT backend needs `make artifacts` first; without it the example
//! still runs the two native backends and says so.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve
//! # intra x inter core-budget split for the native backends:
//! cargo run --release --example e2e_serve -- --replicas 4 --threads 1
//! ```
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::time::{Duration, Instant};
use swconv::coordinator::{BackendSpec, BatchPolicy, Coordinator};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::runtime::engine::default_artifacts_dir;
use swconv::tensor::Tensor;

const N_REQUESTS: usize = 96;
const CLASSES: usize = 10;

/// Synthetic "digit": a bright axis-aligned bar whose angle/offset depends
/// on the seed — structured enough that different inputs give different
/// class scores.
fn synth_digit(seed: u64) -> Tensor {
    let mut t = Tensor::zeros(&[1, 28, 28]);
    let row = (seed % 20 + 4) as usize;
    let col = (seed / 3 % 20 + 4) as usize;
    for i in 0..28 {
        *t.as_mut_slice().get_mut(row * 28 + i).unwrap() = 1.0;
        *t.as_mut_slice().get_mut(i * 28 + col).unwrap() = 1.0;
    }
    t
}

/// `--flag N` lookup over the example's argv (no parser dependency).
fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a.as_str() == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    // The native backends' core budget: `--replicas` worker replicas per
    // backend (inter-request), each with `--threads` kernel threads
    // (intra-request). Defaults reproduce the single-replica setup.
    let replicas = flag("--replicas", 1).max(1);
    let threads = flag("--threads", 1).max(1);
    let artifacts = default_artifacts_dir();
    let have_artifacts = artifacts.join("manifest.json").exists();

    // When artifacts exist, serve the *identical* weights the PJRT model
    // artifact baked in (aot.py exports them as simple_cnn_weights.bin);
    // otherwise fall back to the deterministic Rust-side init.
    let weights = artifacts.join("simple_cnn_weights.bin");
    let load = || -> swconv::nn::Model {
        if weights.exists() {
            zoo::simple_cnn_from_weights_file(&weights, CLASSES).expect("weights file readable")
        } else {
            zoo::simple_cnn(CLASSES, 42)
        }
    };
    let model_sliding = load();
    let model_gemm = load();

    let mut backends = vec![
        BackendSpec::native(
            "sliding",
            model_sliding,
            ExecCtx::with_threads(ConvAlgo::Sliding, threads),
        )
        .with_replicas(replicas),
        BackendSpec::native(
            "gemm",
            model_gemm,
            ExecCtx::with_threads(ConvAlgo::Im2colGemm, threads),
        )
        .with_replicas(replicas),
    ];
    if have_artifacts {
        backends.push(BackendSpec::pjrt(
            "pjrt",
            &artifacts,
            "model_simple_cnn_sliding_b8",
            vec![1, 28, 28],
        ));
    } else {
        eprintln!("NOTE: no artifacts/ found — run `make artifacts` to add the pjrt backend");
    }
    let names: Vec<String> = backends.iter().map(|b| b.name.clone()).collect();

    let coord = Coordinator::new(
        backends,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    );

    println!(
        "serving {N_REQUESTS} requests per backend over backends {names:?} \
         ({replicas} replica(s) x {threads} kernel thread(s) for native)\n"
    );
    let mut all_outputs: Vec<(String, Vec<Tensor>)> = Vec::new();
    for name in &names {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..N_REQUESTS)
            .map(|i| coord.submit(name, synth_digit(i as u64)).expect("submit"))
            .collect();
        let mut outs = Vec::with_capacity(N_REQUESTS);
        for rx in rxs {
            let resp = rx.recv().expect("worker alive");
            outs.push(resp.output.expect("inference ok"));
        }
        let wall = t0.elapsed();
        let m = coord.metrics(name).unwrap();
        println!(
            "{name:>8}: {:>7.1} req/s  | {}",
            N_REQUESTS as f64 / wall.as_secs_f64(),
            m.summary()
        );
        all_outputs.push((name.clone(), outs));
    }

    // Numeric cross-check: every backend serves the same weights, so all
    // outputs must agree (pjrt goes through XLA's CPU codegen — different
    // FP association — hence the slightly looser tolerance).
    println!();
    let (base_name, base) = &all_outputs[0];
    for (name, outs) in &all_outputs[1..] {
        let tol = if name == "pjrt" { 1e-4 } else { 1e-5 };
        let mut worst = 0.0f32;
        for (a, b) in base.iter().zip(outs) {
            worst = worst.max(a.max_abs_diff(b));
        }
        let verdict = if worst < tol { "AGREE" } else { "DIFFER" };
        println!("{base_name} vs {name:>8}: max|diff| = {worst:.3e}  [{verdict}]");
        assert!(worst < tol, "{base_name} vs {name} diverged: {worst}");
    }

    // Argmax agreement (the user-visible answer).
    let argmax = |t: &Tensor| -> usize {
        t.as_slice()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    let mut label_mismatch = 0;
    for i in 0..N_REQUESTS {
        let l0 = argmax(&all_outputs[0].1[i]);
        for (_, outs) in &all_outputs[1..] {
            if argmax(&outs[i]) != l0 {
                label_mismatch += 1;
            }
        }
    }
    println!("predicted labels: {label_mismatch} mismatches across backends");
    assert_eq!(label_mismatch, 0);

    coord.shutdown();
    println!("\ne2e_serve OK — all layers compose (L1 pallas → L2 jax → HLO → L3 rust serving)");
}
